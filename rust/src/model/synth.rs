//! Synthetic artifact generation: a deterministic MiniLlama manifest +
//! weight set + token streams built entirely in-process, so the
//! interpreter backend (and therefore the whole search/eval/serve
//! pipeline) runs with `rust/artifacts/` absent.
//!
//! The parameter registry mirrors `python/compile/model.py` exactly
//! (same names, shapes, order, quantized set, gram sites). Weights are
//! drawn from a uniform distribution with 1/fan_in variance using only
//! [`Rng`] bit-twiddling and IEEE +/-/*/sqrt — no transcendentals — so
//! the Python golden generator (`python/compile/interp_golden.py`)
//! reproduces every f32 bit exactly from the same seed.
//!
//! Two entry points:
//! * [`manifest`] / [`weight_store`] / [`token_stream`] — in-memory,
//!   for unit tests and the golden cross-check;
//! * [`write_artifacts`] — serializes the same data as a real artifact
//!   directory (`manifest.json`, `weights.bin`, `calib.bin`,
//!   `eval.bin`, `tasks.bin`, no HLO), so every file-loading path
//!   (serve router workers, `Pipeline::load`, the CLI) works unchanged.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::{DatasetInfo, ExecInfo, GramSite, Manifest, ModelConfig, ParamInfo, WeightStore};
use crate::calib::TokenStream;
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Seed offsets for the derived dataset streams. The Python golden
/// generator does not consume these — it draws its own token stream
/// whose seed-xor is recorded in `rust/tests/data/interp_golden.json`
/// and read back by the golden test, so the cross-language contract is
/// the recorded file, not a pair of constants.
pub const CALIB_SEED_XOR: u64 = 0xca11b;
pub const EVAL_SEED_XOR: u64 = 0xe7a1;
pub const TASKS_SEED_XOR: u64 = 0x7a5c;

/// Shape of the synthetic model + datasets.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub block_rows: usize,
    pub block_cols: usize,
    /// Static batch of every executable except `qlogits_b1`.
    pub batch: usize,
    pub seed: u64,
    pub calib_tokens: usize,
    pub eval_tokens: usize,
    pub n_tasks: usize,
}

impl Default for SynthSpec {
    fn default() -> SynthSpec {
        SynthSpec {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            seq_len: 32,
            block_rows: 16,
            block_cols: 16,
            batch: 4,
            seed: 7,
            calib_tokens: 4096,
            eval_tokens: 2048,
            n_tasks: 32,
        }
    }
}

/// Parameter names in canonical manifest order (the L2 registry).
fn param_names(spec: &SynthSpec) -> Vec<String> {
    let mut names = vec!["embed".to_string()];
    for i in 0..spec.n_layers {
        for leaf in ["attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"] {
            names.push(format!("layers.{i}.{leaf}"));
        }
    }
    names.push("final_norm".to_string());
    names.push("lm_head".to_string());
    names
}

fn param_shape(spec: &SynthSpec, name: &str) -> Vec<usize> {
    let (v, d, f) = (spec.vocab, spec.d_model, spec.d_ff);
    let leaf = name.rsplit('.').next().unwrap_or(name);
    match leaf {
        "embed" | "lm_head" => vec![v, d],
        "attn_norm" | "mlp_norm" | "final_norm" => vec![d],
        "wq" | "wk" | "wv" | "wo" => vec![d, d],
        "w_gate" | "w_up" => vec![f, d],
        "w_down" => vec![d, f],
        other => unreachable!("unknown param leaf {other}"),
    }
}

fn is_quantized(name: &str) -> bool {
    let leaf = name.rsplit('.').next().unwrap_or(name);
    matches!(leaf, "wq" | "wk" | "wv" | "wo" | "w_gate" | "w_up" | "w_down")
}

/// Build the in-memory manifest. `dir` is recorded as the artifact
/// directory (used only by file-loading paths; the in-memory pipeline
/// never touches it).
pub fn manifest(spec: &SynthSpec, dir: &Path) -> Manifest {
    let config = ModelConfig {
        vocab: spec.vocab,
        d_model: spec.d_model,
        n_layers: spec.n_layers,
        n_heads: spec.n_heads,
        d_ff: spec.d_ff,
        seq_len: spec.seq_len,
        block_rows: spec.block_rows,
        block_cols: spec.block_cols,
    };
    let names = param_names(spec);
    let mut params = Vec::with_capacity(names.len());
    let mut offset = 0usize;
    for name in &names {
        let shape = param_shape(spec, name);
        let numel: usize = shape.iter().product();
        params.push(ParamInfo {
            name: name.clone(),
            shape,
            offset,
            quantized: is_quantized(name),
        });
        offset += numel;
    }
    let quantized: Vec<String> = names.iter().filter(|n| is_quantized(n)).cloned().collect();
    let n_blocks: usize = quantized
        .iter()
        .map(|n| {
            let s = param_shape(spec, n);
            (s[0] / spec.block_rows) * (s[1] / spec.block_cols)
        })
        .sum();

    let sig: Vec<String> = std::iter::once("tokens".to_string())
        .chain(quantized.iter().map(|n| format!("bits:{n}")))
        .chain(names.iter().map(|n| format!("param:{n}")))
        .collect();
    let mut gram_sites = Vec::with_capacity(4 * spec.n_layers);
    for i in 0..spec.n_layers {
        gram_sites.push(GramSite {
            site: format!("layers.{i}.attn_in"),
            dim: spec.d_model,
            consumers: ["wq", "wk", "wv"].iter().map(|w| format!("layers.{i}.{w}")).collect(),
        });
        gram_sites.push(GramSite {
            site: format!("layers.{i}.wo_in"),
            dim: spec.d_model,
            consumers: vec![format!("layers.{i}.wo")],
        });
        gram_sites.push(GramSite {
            site: format!("layers.{i}.mlp_in"),
            dim: spec.d_model,
            consumers: vec![format!("layers.{i}.w_gate"), format!("layers.{i}.w_up")],
        });
        gram_sites.push(GramSite {
            site: format!("layers.{i}.down_in"),
            dim: spec.d_ff,
            consumers: vec![format!("layers.{i}.w_down")],
        });
    }

    let mut executables = HashMap::new();
    let mut add_exec = |name: &str, batch: usize, outputs: Vec<String>| {
        executables.insert(
            name.to_string(),
            ExecInfo {
                file: format!("{name}.hlo.txt"),
                batch,
                inputs: sig.clone(),
                outputs,
            },
        );
    };
    add_exec("qloss", spec.batch, vec!["loss".into()]);
    add_exec(
        "qgrad",
        spec.batch,
        std::iter::once("loss".to_string())
            .chain(quantized.iter().map(|n| format!("grad:{n}")))
            .collect(),
    );
    add_exec("qlogits", spec.batch, vec!["logits".into()]);
    add_exec("qlogits_b1", 1, vec!["logits".into()]);
    add_exec("qpredict", spec.batch, vec!["pred".into()]);
    add_exec(
        "grams",
        spec.batch,
        std::iter::once("loss".to_string())
            .chain(gram_sites.iter().map(|g| g.site.clone()))
            .collect(),
    );

    let mut datasets = HashMap::new();
    datasets.insert(
        "calib".to_string(),
        DatasetInfo { file: "calib.bin".into(), n_tokens: spec.calib_tokens },
    );
    datasets.insert(
        "eval".to_string(),
        DatasetInfo { file: "eval.bin".into(), n_tokens: spec.eval_tokens },
    );

    Manifest {
        dir: dir.to_path_buf(),
        config,
        params,
        quantized,
        n_blocks,
        executables,
        gram_sites,
        datasets,
        tasks_n: spec.n_tasks,
        tasks_seq_len: spec.seq_len,
        synthetic: true,
    }
}

/// Deterministic weights: 1-D params are ones; matrices are uniform in
/// ±sqrt(3/fan_in) (unit-variance-scaled, transcendental-free so the
/// Python mirror is bit-exact). One RNG stream, manifest order.
pub fn weight_store(m: &Manifest, seed: u64) -> WeightStore {
    let mut rng = Rng::new(seed);
    let mut mats = HashMap::new();
    let mut order = Vec::new();
    for p in &m.params {
        let data: Vec<f32> = if p.shape.len() == 1 {
            vec![1.0f32; p.numel()]
        } else {
            let a = (3.0f64 / p.cols() as f64).sqrt();
            (0..p.numel()).map(|_| ((rng.f64() * 2.0 - 1.0) * a) as f32).collect()
        };
        mats.insert(p.name.clone(), Mat::from_vec(p.rows(), p.cols(), data).expect("shape"));
        order.push(p.name.clone());
    }
    WeightStore { mats, order }
}

/// Deterministic uniform token stream over `[0, vocab)`.
pub fn token_stream(n: usize, vocab: usize, seed: u64) -> TokenStream {
    let mut rng = Rng::new(seed);
    TokenStream { tokens: (0..n).map(|_| rng.below(vocab) as i32).collect() }
}

/// Example/bench artifact fallback. An EXPLICITLY passed artifact path
/// must exist — a typo'd `--artifacts` flag is an error, never a toy
/// model silently standing in for the real one (the PR-2 rule: auto
/// never fabricates). Only the implicit default (`explicit == None`,
/// probing `artifacts/`) falls back: the deterministic synthetic model
/// is installed once into a stable, tag-versioned temp dir and reused
/// across runs — `BackendKind::Auto` then resolves to the interpreter,
/// since the synthetic set carries no HLO files. One shared helper so
/// the artifact-less entry points (examples, `ci.sh --examples-smoke`)
/// cannot drift out of sync on the dir name, probe, or install.
///
/// Concurrent first runs are safe: each writes to a PID-suffixed
/// scratch dir and renames it into place (same pattern as the
/// integration tests' shared synth dir); the rename loser discards its
/// copy. Bump the `-v1` suffix whenever the synth format or
/// `SynthSpec::default()` changes, or stale cached artifacts survive.
pub fn artifacts_or_synth(explicit: Option<String>, tag: &str) -> Result<std::path::PathBuf> {
    if let Some(p) = explicit {
        let p = std::path::PathBuf::from(p);
        anyhow::ensure!(
            p.join("manifest.json").exists(),
            "{}: no manifest.json — an explicit --artifacts path is never substituted \
             with a synthetic model (run `make artifacts`, or drop the flag to use \
             the interpreter fallback)",
            p.display()
        );
        return Ok(p);
    }
    let preferred = std::path::PathBuf::from("artifacts");
    if preferred.join("manifest.json").exists() {
        return Ok(preferred);
    }
    let dir = std::env::temp_dir().join(format!("scalebits-{tag}-synth-v1"));
    if !dir.join("manifest.json").exists() {
        let scratch =
            std::env::temp_dir().join(format!("scalebits-{tag}-synth-v1.{}", std::process::id()));
        write_artifacts(&scratch, &SynthSpec::default())?;
        if std::fs::rename(&scratch, &dir).is_err() {
            // Lost the race to a concurrent run that installed the same
            // deterministic content; drop our scratch copy.
            let _ = std::fs::remove_dir_all(&scratch);
            anyhow::ensure!(
                dir.join("manifest.json").exists(),
                "synthetic artifact install failed at {}",
                dir.display()
            );
        }
    }
    println!(
        "no {} — interpreter backend over a synthetic model ({})",
        preferred.display(),
        dir.display()
    );
    Ok(dir)
}

/// Write a complete artifact directory (minus HLO files) so every
/// file-loading path works against the interpreter backend.
pub fn write_artifacts(dir: &Path, spec: &SynthSpec) -> Result<Manifest> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
    let m = manifest(spec, dir);
    let store = weight_store(&m, spec.seed);

    // weights.bin: f32 little-endian, manifest order.
    let total: usize = m.params.iter().map(|p| p.numel()).sum();
    let mut bytes = Vec::with_capacity(total * 4);
    for p in &m.params {
        for &x in &store.get(&p.name)?.data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::write(dir.join("weights.bin"), &bytes)?;

    let write_tokens = |file: &str, n: usize, seed: u64| -> Result<()> {
        let ts = token_stream(n, spec.vocab, seed);
        let mut b = Vec::with_capacity(n * 4);
        for &t in &ts.tokens {
            b.extend_from_slice(&t.to_le_bytes());
        }
        std::fs::write(dir.join(file), &b)?;
        Ok(())
    };
    write_tokens("calib.bin", spec.calib_tokens, spec.seed ^ CALIB_SEED_XOR)?;
    write_tokens("eval.bin", spec.eval_tokens, spec.seed ^ EVAL_SEED_XOR)?;
    write_tokens("tasks.bin", spec.n_tasks * spec.seq_len, spec.seed ^ TASKS_SEED_XOR)?;

    // manifest.json, in the exact shape Manifest::load parses.
    let mut params_j = Vec::with_capacity(m.params.len());
    for p in &m.params {
        params_j.push(Json::from_pairs(vec![
            ("name", Json::Str(p.name.clone())),
            ("shape", Json::arr_usize(&p.shape)),
            ("offset", Json::Num(p.offset as f64)),
            ("quantized", Json::Bool(p.quantized)),
        ]));
    }
    let mut execs_j = Json::obj();
    for (name, e) in &m.executables {
        execs_j.set(
            name,
            Json::from_pairs(vec![
                ("file", Json::Str(e.file.clone())),
                ("batch", Json::Num(e.batch as f64)),
                ("inputs", Json::arr_str(&e.inputs)),
                ("outputs", Json::arr_str(&e.outputs)),
            ]),
        );
    }
    let mut sites_j = Vec::with_capacity(m.gram_sites.len());
    for g in &m.gram_sites {
        sites_j.push(Json::from_pairs(vec![
            ("site", Json::Str(g.site.clone())),
            ("dim", Json::Num(g.dim as f64)),
            ("consumers", Json::arr_str(&g.consumers)),
        ]));
    }
    let datasets_j = Json::from_pairs(vec![
        (
            "calib",
            Json::from_pairs(vec![
                ("file", Json::Str("calib.bin".into())),
                ("n_tokens", Json::Num(spec.calib_tokens as f64)),
            ]),
        ),
        (
            "eval",
            Json::from_pairs(vec![
                ("file", Json::Str("eval.bin".into())),
                ("n_tokens", Json::Num(spec.eval_tokens as f64)),
            ]),
        ),
        (
            "tasks",
            Json::from_pairs(vec![
                ("file", Json::Str("tasks.bin".into())),
                ("n", Json::Num(spec.n_tasks as f64)),
                ("seq_len", Json::Num(spec.seq_len as f64)),
            ]),
        ),
    ]);
    let manifest_j = Json::from_pairs(vec![
        (
            "config",
            Json::from_pairs(vec![
                ("vocab", Json::Num(spec.vocab as f64)),
                ("d_model", Json::Num(spec.d_model as f64)),
                ("n_layers", Json::Num(spec.n_layers as f64)),
                ("n_heads", Json::Num(spec.n_heads as f64)),
                ("d_ff", Json::Num(spec.d_ff as f64)),
                ("seq_len", Json::Num(spec.seq_len as f64)),
                ("block_rows", Json::Num(spec.block_rows as f64)),
                ("block_cols", Json::Num(spec.block_cols as f64)),
            ]),
        ),
        ("params", Json::Arr(params_j)),
        ("quantized", Json::arr_str(&m.quantized)),
        ("n_blocks", Json::Num(m.n_blocks as f64)),
        ("executables", execs_j),
        ("gram_sites", Json::Arr(sites_j)),
        ("datasets", datasets_j),
        ("synthetic", Json::Bool(true)),
    ]);
    manifest_j.write_file(&dir.join("manifest.json"))?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BlockIndex;

    #[test]
    fn synthetic_manifest_is_self_consistent() {
        let spec = SynthSpec::default();
        let m = manifest(&spec, Path::new("unused"));
        let index = BlockIndex::from_manifest(&m).unwrap();
        assert_eq!(index.n_blocks, m.n_blocks);
        assert_eq!(m.quantized.len(), 7 * spec.n_layers);
        let store = weight_store(&m, spec.seed);
        assert_eq!(store.order.len(), m.params.len());
        for p in &m.params {
            let mat = store.get(&p.name).unwrap();
            assert_eq!(mat.data.len(), p.numel(), "{}", p.name);
            assert!(mat.data.iter().all(|x| x.is_finite()));
        }
        // norms are ones, matrices are bounded by +/-sqrt(3/fan_in)
        assert!(store.get("final_norm").unwrap().data.iter().all(|&x| x == 1.0));
        let wq = store.get("layers.0.wq").unwrap();
        let bound = (3.0f64 / spec.d_model as f64).sqrt() as f32 + 1e-6;
        assert!(wq.data.iter().all(|&x| x.abs() <= bound));
    }

    #[test]
    fn written_artifacts_reload_identically() {
        let spec = SynthSpec::default();
        let dir = std::env::temp_dir().join(format!("scalebits-synth-test-{}", std::process::id()));
        let m = write_artifacts(&dir, &spec).unwrap();
        let loaded = Manifest::load(&dir).unwrap();
        assert_eq!(loaded.n_blocks, m.n_blocks);
        assert_eq!(loaded.quantized, m.quantized);
        assert_eq!(loaded.params.len(), m.params.len());
        assert_eq!(loaded.config.seq_len, m.config.seq_len);
        let store_mem = weight_store(&m, spec.seed);
        let store_disk = WeightStore::load(&loaded).unwrap();
        for p in &m.params {
            assert_eq!(
                store_mem.get(&p.name).unwrap().data,
                store_disk.get(&p.name).unwrap().data,
                "{}",
                p.name
            );
        }
        let ts_mem = token_stream(spec.eval_tokens, spec.vocab, spec.seed ^ EVAL_SEED_XOR);
        let ts_disk = TokenStream::from_manifest(&loaded, "eval").unwrap();
        assert_eq!(ts_mem.tokens, ts_disk.tokens);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn token_stream_stays_in_vocab() {
        let ts = token_stream(1000, 64, 3);
        assert!(ts.tokens.iter().all(|&t| (0..64).contains(&t)));
    }
}
