//! Model manifest + weight store.
//!
//! Mirrors `python/compile/model.py`'s canonical parameter registry.
//! The manifest pins the exact positional argument order of every AOT
//! executable, so the rust side never guesses shapes or ordering.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Mat;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub block_rows: usize,
    pub block_cols: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub quantized: bool,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        if self.shape.len() > 1 {
            self.shape[1]
        } else {
            1
        }
    }
}

#[derive(Clone, Debug)]
pub struct ExecInfo {
    pub file: String,
    pub batch: usize,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct GramSite {
    pub site: String,
    pub dim: usize,
    /// Quantized matrices whose input activation this Gram describes.
    pub consumers: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub file: String,
    pub n_tokens: usize,
}

#[derive(Clone, Debug)]
pub struct KernelBenchInfo {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub block_rows: usize,
    pub block_cols: usize,
    pub files: HashMap<String, String>,
    pub elemmp_n_outliers: usize,
}

/// The parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub params: Vec<ParamInfo>,
    pub quantized: Vec<String>,
    pub n_blocks: usize,
    pub executables: HashMap<String, ExecInfo>,
    pub gram_sites: Vec<GramSite>,
    pub datasets: HashMap<String, DatasetInfo>,
    pub tasks_n: usize,
    pub tasks_seq_len: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::read_file(&dir.join("manifest.json"))
            .context("loading manifest.json — run `make artifacts` first")?;
        let c = j.get("config")?;
        let config = ModelConfig {
            vocab: c.get("vocab")?.as_usize()?,
            d_model: c.get("d_model")?.as_usize()?,
            n_layers: c.get("n_layers")?.as_usize()?,
            n_heads: c.get("n_heads")?.as_usize()?,
            d_ff: c.get("d_ff")?.as_usize()?,
            seq_len: c.get("seq_len")?.as_usize()?,
            block_rows: c.get("block_rows")?.as_usize()?,
            block_cols: c.get("block_cols")?.as_usize()?,
        };
        let mut params = Vec::new();
        for p in j.get("params")?.as_arr()? {
            params.push(ParamInfo {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p.get("shape")?.to_vec_usize()?,
                offset: p.get("offset")?.as_usize()?,
                quantized: p.get("quantized")?.as_bool()?,
            });
        }
        let quantized: Vec<String> = j
            .get("quantized")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let mut executables = HashMap::new();
        for (name, e) in j.get("executables")?.as_obj()? {
            executables.insert(
                name.clone(),
                ExecInfo {
                    file: e.get("file")?.as_str()?.to_string(),
                    batch: e.get("batch")?.as_usize()?,
                    inputs: e
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(|x| Ok(x.as_str()?.to_string()))
                        .collect::<Result<_>>()?,
                    outputs: e
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(|x| Ok(x.as_str()?.to_string()))
                        .collect::<Result<_>>()?,
                },
            );
        }
        let mut gram_sites = Vec::new();
        for g in j.get("gram_sites")?.as_arr()? {
            gram_sites.push(GramSite {
                site: g.get("site")?.as_str()?.to_string(),
                dim: g.get("dim")?.as_usize()?,
                consumers: g
                    .get("consumers")?
                    .as_arr()?
                    .iter()
                    .map(|x| Ok(x.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
            });
        }
        let mut datasets = HashMap::new();
        for (name, d) in j.get("datasets")?.as_obj()? {
            if name == "tasks" {
                continue;
            }
            datasets.insert(
                name.clone(),
                DatasetInfo {
                    file: d.get("file")?.as_str()?.to_string(),
                    n_tokens: d.get("n_tokens")?.as_usize()?,
                },
            );
        }
        let tasks = j.get("datasets")?.get("tasks")?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            config,
            params,
            quantized,
            n_blocks: j.get("n_blocks")?.as_usize()?,
            executables,
            gram_sites,
            datasets,
            tasks_n: tasks.get("n")?.as_usize()?,
            tasks_seq_len: tasks.get("seq_len")?.as_usize()?,
        })
    }

    pub fn kernel_bench(&self) -> Result<KernelBenchInfo> {
        let j = Json::read_file(&self.dir.join("manifest.json"))?;
        let k = j.get("kernel_bench")?;
        let mut files = HashMap::new();
        for (name, f) in k.get("files")?.as_obj()? {
            files.insert(name.clone(), f.as_str()?.to_string());
        }
        Ok(KernelBenchInfo {
            m: k.get("m")?.as_usize()?,
            n: k.get("n")?.as_usize()?,
            k: k.get("k")?.as_usize()?,
            block_rows: k.get("block_rows")?.as_usize()?,
            block_cols: k.get("block_cols")?.as_usize()?,
            files,
            elemmp_n_outliers: k.get("elemmp_n_outliers")?.as_usize()?,
        })
    }

    pub fn param(&self, name: &str) -> Result<&ParamInfo> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow!("unknown param {name:?}"))
    }

    pub fn exec(&self, name: &str) -> Result<&ExecInfo> {
        self.executables.get(name).ok_or_else(|| anyhow!("unknown executable {name:?}"))
    }

    /// Block-grid shape of a quantized matrix.
    pub fn bits_shape(&self, name: &str) -> Result<(usize, usize)> {
        let p = self.param(name)?;
        if !p.quantized {
            bail!("{name:?} is not quantized");
        }
        Ok((p.rows() / self.config.block_rows, p.cols() / self.config.block_cols))
    }

    /// Total quantizable weight elements (the budget denominator).
    pub fn quantized_numel(&self) -> usize {
        self.params.iter().filter(|p| p.quantized).map(|p| p.numel()).sum()
    }
}

/// Full-precision weights, loaded once from `weights.bin`, addressable
/// by name. All transformations (reordering, quantization previews)
/// work on copies — the store itself is the pristine trained model.
#[derive(Clone)]
pub struct WeightStore {
    pub mats: HashMap<String, Mat>,
    pub order: Vec<String>,
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let path = manifest.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow!("read {}: {e} — run `make artifacts`", path.display()))?;
        let total: usize = manifest.params.iter().map(|p| p.numel()).sum();
        if bytes.len() != total * 4 {
            bail!("weights.bin: expected {} f32s, got {} bytes", total, bytes.len());
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut mats = HashMap::new();
        let mut order = Vec::new();
        for p in &manifest.params {
            let data = floats[p.offset..p.offset + p.numel()].to_vec();
            mats.insert(p.name.clone(), Mat::from_vec(p.rows(), p.cols(), data)?);
            order.push(p.name.clone());
        }
        Ok(WeightStore { mats, order })
    }

    pub fn get(&self, name: &str) -> Result<&Mat> {
        self.mats.get(name).ok_or_else(|| anyhow!("missing weight {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Mat> {
        self.mats.get_mut(name).ok_or_else(|| anyhow!("missing weight {name:?}"))
    }

    /// Weights flattened in manifest order (the executables' layout).
    pub fn in_order(&self) -> Vec<(&str, &Mat)> {
        self.order.iter().map(|n| (n.as_str(), &self.mats[n])).collect()
    }
}

/// Split "layers.2.wq" -> (Some(2), "wq"); "embed" -> (None, "embed").
pub fn split_param_name(name: &str) -> (Option<usize>, &str) {
    let parts: Vec<&str> = name.split('.').collect();
    if parts.len() == 3 && parts[0] == "layers" {
        (parts[1].parse().ok(), parts[2])
    } else {
        (None, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_names() {
        assert_eq!(split_param_name("layers.2.wq"), (Some(2), "wq"));
        assert_eq!(split_param_name("embed"), (None, "embed"));
        assert_eq!(split_param_name("final_norm"), (None, "final_norm"));
    }
}
