//! Cross-layer integration net: search invariants, gradient
//! consistency, reordering equivalence, serving round-trip, transfer
//! accounting, packfile roundtrip.
//!
//! Backend selection: when `artifacts/` holds real AOT-lowered HLO
//! (run `make artifacts`), the net runs on the PJRT engine — plus a
//! handful of PJRT-only tests (Pallas golden cross-validation, kernel
//! executables). When artifacts are absent — or `SCALEBITS_BACKEND=
//! interp` forces it — the same net runs on the pure-Rust interpreter
//! over a deterministic synthetic artifact set written to a temp dir,
//! so `cargo test` exercises every layer in an artifact-less container
//! instead of asserting about missing files.

use std::path::PathBuf;
use std::sync::OnceLock;

use scalebits::calib::{BatchSampler, TokenStream};
use scalebits::coordinator::Pipeline;
use scalebits::model::synth::{self, SynthSpec};
use scalebits::model::{Manifest, WeightStore};
use scalebits::quant::{fakequant_mat, quant_group_codes, BitAlloc, BlockIndex};
use scalebits::runtime::{ActPrecision, BackendKind, Engine, ExecBackend, InterpBackend, Session};
use scalebits::search::SearchConfig;
use scalebits::tensor::Mat;
use scalebits::util::json::Json;

fn real_artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn force_interp() -> bool {
    // `SCALEBITS_BACKEND` goes through the util::env registry like
    // every other SCALEBITS_* variable (raw reads are a lint failure).
    scalebits::util::env::backend_override() == Some("interp")
}

/// Real PJRT artifacts present and not overridden?
fn pjrt_available() -> bool {
    !force_interp()
        && real_artifacts().join("manifest.json").exists()
        && real_artifacts().join("qloss.hlo.txt").exists()
}

/// Synthetic artifact dir: one stable, version-tagged location in the
/// system temp dir, installed atomically (write to a PID-suffixed
/// scratch dir, rename into place) so concurrent test runs can share
/// it and repeated runs don't accumulate litter. Bump the tag when the
/// synth format changes.
fn synth_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let base = std::env::temp_dir().join("scalebits-it-synth-v1");
        if base.join("manifest.json").exists() {
            return base;
        }
        let tmp = std::env::temp_dir()
            .join(format!("scalebits-it-synth-v1.{}", std::process::id()));
        synth::write_artifacts(&tmp, &SynthSpec::default()).expect("write synth artifacts");
        if std::fs::rename(&tmp, &base).is_err() {
            // Lost the race to a concurrent run that installed the same
            // deterministic content; drop our scratch copy.
            let _ = std::fs::remove_dir_all(&tmp);
            assert!(base.join("manifest.json").exists(), "synth artifacts install failed");
        }
        base
    })
}

/// Backend + artifact dir the cross-layer net runs on.
fn setup() -> (BackendKind, PathBuf) {
    if pjrt_available() {
        (BackendKind::PjrtCpu, real_artifacts())
    } else {
        (BackendKind::Interp, synth_dir().clone())
    }
}

macro_rules! require_pjrt {
    () => {
        if !pjrt_available() {
            eprintln!("skipping: needs real PJRT artifacts (run `make artifacts`)");
            return;
        }
    };
}

// ---------------------------------------------------------------------
// golden cross-validation: rust RTN mirror vs the Pallas reference

#[test]
fn golden_fakequant_matches_python() {
    require_pjrt!();
    let g = Json::read_file(&real_artifacts().join("golden.json")).unwrap();
    let fq = g.get("fakequant").unwrap();
    let rows = fq.get("rows").unwrap().as_usize().unwrap();
    let cols = fq.get("cols").unwrap().as_usize().unwrap();
    let w = Mat::from_vec(rows, cols, fq.get("w").unwrap().to_vec_f32().unwrap()).unwrap();
    let bits = fq.get("bits").unwrap().to_vec_i32().unwrap();
    let want = fq.get("out").unwrap().to_vec_f32().unwrap();
    let br = fq.get("block_rows").unwrap().as_usize().unwrap();
    let bc = fq.get("block_cols").unwrap().as_usize().unwrap();
    let got = fakequant_mat(&w, &bits, br, bc);
    for i in 0..want.len() {
        assert!(
            (got.data[i] - want[i]).abs() < 1e-5,
            "elem {i}: rust {} vs python {}",
            got.data[i],
            want[i]
        );
    }
}

#[test]
fn golden_codes_match_python() {
    require_pjrt!();
    let g = Json::read_file(&real_artifacts().join("golden.json")).unwrap();
    let c = g.get("codes4").unwrap();
    let rows = c.get("rows").unwrap().as_usize().unwrap();
    let cols = c.get("cols").unwrap().as_usize().unwrap();
    let group = c.get("group").unwrap().as_usize().unwrap();
    let w = Mat::from_vec(rows, cols, c.get("w").unwrap().to_vec_f32().unwrap()).unwrap();
    let want_codes = c.get("codes").unwrap().to_vec_i32().unwrap();
    let want_scales = c.get("scales").unwrap().to_vec_f32().unwrap();
    let ngroups = cols / group;
    for r in 0..rows {
        for gidx in 0..ngroups {
            let seg: Vec<f32> =
                (0..group).map(|j| w.at(r, gidx * group + j)).collect();
            let (codes, scale) = quant_group_codes(&seg, 4);
            let s_want = want_scales[r * ngroups + gidx];
            assert!(
                (scale - s_want).abs() <= 1e-6 * s_want.abs().max(1e-6),
                "scale ({r},{gidx}): {scale} vs {s_want}"
            );
            for j in 0..group {
                let want = want_codes[r * cols + gidx * group + j] as i8;
                assert_eq!(codes[j], want, "code ({r},{},{j})", gidx * group + j);
            }
        }
    }
}

// ---------------------------------------------------------------------
// interpreter vs the recorded float64 Python golden

#[test]
fn interp_qloss_matches_python_golden() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("data")
        .join("interp_golden.json");
    let g = Json::read_file(&path).unwrap();
    let s = g.get("spec").unwrap();
    let u = |k: &str| s.get(k).unwrap().as_usize().unwrap();
    let spec = SynthSpec {
        vocab: u("vocab"),
        d_model: u("d_model"),
        n_layers: u("n_layers"),
        n_heads: u("n_heads"),
        d_ff: u("d_ff"),
        seq_len: u("seq_len"),
        block_rows: u("block_rows"),
        block_cols: u("block_cols"),
        batch: u("batch"),
        seed: s.get("seed").unwrap().as_usize().unwrap() as u64,
        ..SynthSpec::default()
    };
    let tok_xor = g.get("token_seed_xor").unwrap().as_usize().unwrap() as u64;
    let manifest = synth::manifest(&spec, std::path::Path::new("unused"));
    let index = BlockIndex::from_manifest(&manifest).unwrap();
    let store = synth::weight_store(&manifest, spec.seed);
    let tokens =
        synth::token_stream(spec.batch * spec.seq_len, spec.vocab, spec.seed ^ tok_xor).tokens;
    let be = InterpBackend::new(manifest, &["qloss"]).unwrap();
    let w = be.upload_weights(&store).unwrap();
    for case in g.get("cases").unwrap().as_arr().unwrap() {
        let bits = case.get("bits").unwrap().as_f64().unwrap() as i32;
        let want = case.get("loss").unwrap().as_f64().unwrap();
        let grids = be.upload_grids(&BitAlloc::uniform(&index, bits).grids(&index)).unwrap();
        let got = be.run_model("qloss", &tokens, &grids, &w).unwrap()[0]
            .scalar_f32()
            .unwrap() as f64;
        assert!(
            (got - want).abs() < 1e-4,
            "bits={bits}: interp {got} vs python golden {want}"
        );
    }
}

// ---------------------------------------------------------------------
// runtime + executables (both backends)

#[test]
fn qloss_fp_is_finite_and_plausible() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss"]).unwrap();
    let mut sampler = p.sampler(7);
    let tokens = sampler.sample(p.batch_of("qloss").unwrap());
    let loss = p.ctx().qloss(&tokens, &p.fp_alloc()).unwrap();
    assert!(loss.is_finite());
    let ln_vocab = (p.manifest().config.vocab as f64).ln();
    if kind == BackendKind::PjrtCpu {
        // trained model: loss well below uniform ln(V) and above 0
        assert!(loss > 0.5 && loss < 5.5, "{loss}");
    } else {
        // synthetic (untrained) model: near the uniform regime
        assert!(loss > 0.5 && loss < 2.0 * ln_vocab, "{loss} vs ln V {ln_vocab}");
    }
}

#[test]
fn qgrad_loss_consistent_with_qloss() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss", "qgrad"]).unwrap();
    let mut sampler = p.sampler(9);
    let tokens = sampler.sample(p.batch_of("qgrad").unwrap());
    let alloc = BitAlloc::uniform(&p.index, 3);
    let l1 = p.ctx().qloss(&tokens, &alloc).unwrap();
    let (l2, grads) = p.ctx().qgrad(&tokens, &alloc).unwrap();
    assert!((l1 - l2).abs() < 1e-5, "{l1} vs {l2}");
    assert_eq!(grads.len(), p.index.mats.len());
    for (mi, g) in grads.iter().enumerate() {
        let name = &p.index.mats[mi];
        let info = p.manifest().param(name).unwrap();
        assert_eq!((g.rows, g.cols), (info.rows(), info.cols()));
        assert!(g.data.iter().all(|x| x.is_finite()), "{name}");
    }
}

#[test]
fn quantization_precision_ladder_on_device() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss"]).unwrap();
    let mut sampler = p.sampler(11);
    let tokens = sampler.sample(p.batch_of("qloss").unwrap());
    let l2 = p.ctx().qloss(&tokens, &BitAlloc::uniform(&p.index, 2)).unwrap();
    let l8 = p.ctx().qloss(&tokens, &BitAlloc::uniform(&p.index, 8)).unwrap();
    let lfp = p.ctx().qloss(&tokens, &p.fp_alloc()).unwrap();
    // 8-bit is a tiny perturbation of FP on any weight set.
    assert!((l8 - lfp).abs() < 0.05, "8-bit ~ FP: {l8} vs {lfp}");
    assert!(l2.is_finite());
    if kind == BackendKind::PjrtCpu {
        // Only a TRAINED model guarantees 2-bit damage shows up as a
        // loss increase; the synthetic model starts near uniform loss.
        assert!(l2 > lfp + 0.05, "2-bit must hurt: {l2} vs {lfp}");
    }
}

#[test]
fn device_fakequant_agrees_with_rust_mirror() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss"]).unwrap();
    let mut sampler = p.sampler(13);
    let tokens = sampler.sample(p.batch_of("qloss").unwrap());
    let alloc3 = BitAlloc::uniform(&p.index, 3);
    let on_device = p.ctx().qloss(&tokens, &alloc3).unwrap();

    let mut store = p.store.clone();
    for (mi, name) in p.index.mats.iter().enumerate() {
        let grid = &alloc3.bits[p.index.mat_range(mi)];
        let wq = fakequant_mat(
            p.store.get(name).unwrap(),
            grid,
            p.index.block_rows,
            p.index.block_cols,
        );
        *store.get_mut(name).unwrap() = wq;
    }
    let bufs = p.backend.upload_weights(&store).unwrap();
    let grids = p.fp_alloc().grids(&p.index);
    let out = p.backend.run_model_host_grids("qloss", &tokens, &grids, &bufs).unwrap();
    let host_side = out[0].scalar_f32().unwrap() as f64;
    assert!(
        (on_device - host_side).abs() < 1e-4,
        "device fakequant {on_device} vs rust fakequant {host_side}"
    );
}

// ---------------------------------------------------------------------
// reordering equivalence (both backends)

#[test]
fn reordering_preserves_model_function() {
    let (kind, dir) = setup();
    let mut p = Pipeline::load_with(kind, &dir, &["qloss", "qgrad", "qlogits"]).unwrap();
    let mut sampler = p.sampler(17);
    let tokens = sampler.sample(p.batch_of("qlogits").unwrap());
    let fp = p.fp_alloc();
    let logits_before = {
        let out = p
            .backend
            .run_model_host_grids("qlogits", &tokens, &fp.grids(&p.index), &p.wbufs)
            .unwrap();
        out[0].to_vec_f32().unwrap()
    };
    let r = p.reorder(3, 42).unwrap();
    assert!(!r.is_identity(), "reordering should move channels");
    let logits_after = {
        let out = p
            .backend
            .run_model_host_grids("qlogits", &tokens, &fp.grids(&p.index), &p.wbufs)
            .unwrap();
        out[0].to_vec_f32().unwrap()
    };
    let mut max_abs = 0.0f32;
    for (a, b) in logits_before.iter().zip(&logits_after) {
        max_abs = max_abs.max((a - b).abs());
    }
    assert!(max_abs < 2e-3, "logits diverged after reorder: {max_abs}");
}

// ---------------------------------------------------------------------
// search invariants (both backends)

#[test]
fn short_search_respects_invariants() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss", "qgrad"]).unwrap();
    let cfg = SearchConfig { budget: 3.0, max_iters: 6, seed: 5, ..Default::default() };
    let res = p.search(&cfg).unwrap();
    // bit bounds
    assert!(res.alloc.bits.iter().all(|&b| (cfg.bits_min..=cfg.bits_max).contains(&b)));
    // budget never exceeded (warm start == ⌊B⌋, expansion capped)
    assert!(res.alloc.avg_bits() <= cfg.budget + 1e-9, "{}", res.alloc.avg_bits());
    // accepted steps never increased the (same-batch) loss
    for it in &res.iters {
        if it.accepted {
            assert!(it.loss_after <= it.loss_before + 1e-9);
        }
    }
    assert!(res.exec_calls >= 2 * res.iters.len() as u64);
    assert!(res.final_loss.is_finite());
}

#[test]
fn search_is_deterministic_under_seed() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss", "qgrad"]).unwrap();
    let cfg = SearchConfig { budget: 2.5, max_iters: 4, seed: 77, ..Default::default() };
    let a = p.search(&cfg).unwrap();
    let b = p.search(&cfg).unwrap();
    assert_eq!(a.alloc.bits, b.alloc.bits);
}

/// Regression: `final_loss` used to stay NaN whenever the loop body
/// never ran (max_iters == 0, or gamma_t > gamma0 making k < k_min at
/// entry). It is now seeded with the warm-start qloss.
#[test]
fn search_final_loss_seeded_when_loop_never_runs() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss", "qgrad"]).unwrap();
    for cfg in [
        SearchConfig { budget: 3.0, max_iters: 0, seed: 3, ..Default::default() },
        SearchConfig { budget: 3.0, gamma0: 0.01, gamma_t: 0.5, seed: 3, ..Default::default() },
    ] {
        let res = p.search(&cfg).unwrap();
        assert!(res.iters.is_empty(), "loop must not run ({cfg:?})");
        assert!(res.final_loss.is_finite(), "final_loss NaN again ({cfg:?})");
        assert!(res.final_loss > 0.0);
    }
}

/// Budget safety across random seeds: the two-stage update may never
/// exceed `cfg.budget` in average bits (previously only a host-side
/// sketch of the exchange stage was tested).
#[test]
fn search_never_exceeds_budget_across_seeds() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss", "qgrad"]).unwrap();
    // 2.21 makes budget*n_blocks fractional: the expansion stage used
    // to overshoot it by one block when under a bit of headroom remained.
    for (seed, budget) in [(1u64, 2.5f64), (2, 3.0), (3, 2.21), (4, 3.5)] {
        let cfg = SearchConfig { budget, max_iters: 4, seed, ..Default::default() };
        let res = p.search(&cfg).unwrap();
        assert!(
            res.alloc.avg_bits() <= budget + 1e-9,
            "seed {seed} budget {budget}: avg {}",
            res.alloc.avg_bits()
        );
        for it in &res.iters {
            assert!(it.avg_bits <= budget + 1e-9, "seed {seed} iter {}: {}", it.iter, it.avg_bits);
        }
    }
}

// ---------------------------------------------------------------------
// grams + eval (both backends)

#[test]
fn grams_are_psd_and_sized() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["grams"]).unwrap();
    let grams = p.grams(&p.fp_alloc(), 1, 3).unwrap();
    assert_eq!(grams.len(), p.index.mats.len());
    for (name, g) in &grams {
        let info = p.manifest().param(name).unwrap();
        assert_eq!(g.n, info.cols(), "{name}");
        // diagonals of X^T X are nonnegative
        for i in 0..g.n {
            assert!(g.at(i, i) >= -1e-6, "{name} diag {i}: {}", g.at(i, i));
        }
    }
}

/// Regression: perplexity on a stream too short for one window used to
/// return exp(0) = 1.0 (a silently "perfect" model); it must error.
#[test]
fn perplexity_errors_on_short_stream() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss"]).unwrap();
    let seq = p.manifest().config.seq_len;
    let short = TokenStream { tokens: vec![1; seq / 2] };
    let r = scalebits::eval::perplexity(
        p.backend.as_ref(),
        &p.wbufs,
        &p.index,
        &BitAlloc::uniform(&p.index, 4),
        &short,
        4,
    );
    assert!(r.is_err(), "short stream must error, got {r:?}");
}

// ---------------------------------------------------------------------
// serving round-trip (both backends)

#[test]
fn server_round_trip() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let alloc = BitAlloc::uniform(&index, 4);
    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc);
    cfg.backend = kind;
    cfg.batch_window = std::time::Duration::from_millis(2);
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let mut tickets = Vec::new();
    for i in 0..5 {
        let tokens = stream.tokens[i * 64..i * 64 + m.config.seq_len].to_vec();
        tickets.push(server.submit(tokens).unwrap());
    }
    for t in &mut tickets {
        let o = t.wait().unwrap();
        assert_eq!(o.finish, scalebits::serve::Finish::Completed);
        assert_eq!(o.tokens.len(), 1, "seed-shim submit asks for one token");
        assert!(o.tokens[0] >= 0 && (o.tokens[0] as usize) < m.config.vocab);
        assert_eq!(o.worker, 0);
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.workers, 1);
    assert_eq!(report.total.served, 5);
    assert_eq!(report.total.completed, 5);
    assert_eq!(report.total.latency.count(), 5);
}

#[test]
fn multi_worker_router_round_trip() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut cfg =
        scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = kind;
    cfg.workers = 2;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let mut tickets = Vec::new();
    for i in 0..8 {
        let tokens = stream.tokens[i * 32..i * 32 + m.config.seq_len].to_vec();
        tickets.push(server.submit(tokens).unwrap());
    }
    let mut seen_workers = std::collections::HashSet::new();
    for t in &mut tickets {
        let o = t.wait().unwrap();
        assert!(o.tokens[0] >= 0 && (o.tokens[0] as usize) < m.config.vocab);
        seen_workers.insert(o.worker);
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.total.served, 8);
    assert_eq!(report.per_worker.len(), 2);
    // round-robin over dedicated queues: both workers must have served
    assert_eq!(seen_workers.len(), 2, "dispatch must spread across workers");
    assert_eq!(
        report.per_worker.iter().map(|w| w.served).sum::<u64>(),
        report.total.served
    );
}

// ---------------------------------------------------------------------
// request lifecycle (both backends unless noted)

#[test]
fn ticket_streams_tokens_incrementally() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = kind;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let mut t = server
        .submit_request(
            scalebits::serve::GenRequest::new(stream.tokens[..m.config.seq_len].to_vec())
                .max_new_tokens(3),
        )
        .unwrap();
    let mut streamed = Vec::new();
    while let Some(ev) = t.recv_token().unwrap() {
        assert_eq!(ev.index, streamed.len(), "tokens must stream in order");
        streamed.push(ev.token);
    }
    let o = t.outcome().expect("terminal after recv_token returns None");
    assert_eq!(o.finish, scalebits::serve::Finish::Completed);
    assert_eq!(o.tokens, streamed, "outcome must carry exactly the streamed tokens");
    assert_eq!(streamed.len(), 3);
    assert!(streamed.iter().all(|&x| x >= 0 && (x as usize) < m.config.vocab));
    let rep = server.shutdown().unwrap();
    assert_eq!(rep.total.decode_tokens, 3);
    assert_eq!(rep.total.first_token.count(), 1, "one TTFT sample per request");
    assert_eq!(
        rep.total.inter_token.count(),
        2,
        "ITL counts token->token gaps only (the first token is TTFT, not ITL)"
    );
}

#[test]
fn cancellation_mid_decode_frees_the_worker_slot() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = kind;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let batch = m.exec(if m.executables.contains_key("qpredict") { "qpredict" } else { "qlogits" })
        .unwrap()
        .batch;
    // Fill the whole decode set with effectively-unbounded generations…
    let mut long = Vec::new();
    for i in 0..batch {
        long.push(
            server
                .submit_request(
                    scalebits::serve::GenRequest::new(
                        stream.tokens[i * 16..i * 16 + seq].to_vec(),
                    )
                    .max_new_tokens(1_000_000),
                )
                .unwrap(),
        );
    }
    // …cancel them all; if cancellation did not free the slots, the
    // short request below could never be admitted and wait() would
    // hang (the test harness would time out).
    for t in &long {
        t.try_cancel();
    }
    let mut short = server.submit(stream.tokens[..seq].to_vec()).unwrap();
    let o = short.wait().unwrap();
    assert_eq!(o.finish, scalebits::serve::Finish::Completed);
    for t in &mut long {
        assert_eq!(t.wait().unwrap().finish, scalebits::serve::Finish::Cancelled);
    }
    let rep = server.shutdown().unwrap();
    assert_eq!(rep.total.cancelled, batch as u64);
    assert_eq!(rep.total.completed, 1);
    assert_eq!(rep.total.served, batch as u64 + 1);
}

#[test]
fn deadline_exceeded_requests_never_occupy_an_iteration() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = kind;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    // Warm the engine so the expired request meets a ready worker.
    let mut warm = server.submit_warmup(stream.tokens[..seq].to_vec()).unwrap();
    warm.wait().unwrap();
    let mut t = server
        .submit_request(
            scalebits::serve::GenRequest::new(stream.tokens[..seq].to_vec())
                .max_new_tokens(4)
                .deadline(std::time::Duration::ZERO),
        )
        .unwrap();
    let o = t.wait().unwrap();
    assert_eq!(o.finish, scalebits::serve::Finish::DeadlineExceeded);
    assert!(o.tokens.is_empty(), "an expired request must not decode");
    let rep = server.shutdown().unwrap();
    assert_eq!(rep.total.deadline_exceeded, 1);
    assert_eq!(rep.total.served, 1);
    assert_eq!(rep.total.decode_tokens, 0);
    assert_eq!(
        rep.total.batches, 0,
        "a deadline-exceeded request must never occupy a decode iteration"
    );
}

#[test]
fn shutdown_drains_the_live_decode_set() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = kind;
    cfg.workers = 2;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let (n, max_new) = (6usize, 5usize);
    let mut tickets = Vec::new();
    for i in 0..n {
        tickets.push(
            server
                .submit_request(
                    scalebits::serve::GenRequest::new(stream.tokens[i * 16..i * 16 + seq].to_vec())
                        .max_new_tokens(max_new),
                )
                .unwrap(),
        );
    }
    // Shut down immediately: every admitted request — queued or
    // mid-decode — must still be decoded to completion.
    let rep = server.shutdown().unwrap();
    for t in &mut tickets {
        let o = t.wait().unwrap();
        assert_eq!(o.finish, scalebits::serve::Finish::Completed);
        assert_eq!(o.tokens.len(), max_new, "shutdown must not truncate generation");
    }
    assert_eq!(rep.total.completed, n as u64);
    assert_eq!(rep.total.decode_tokens, (n * max_new) as u64);
}

#[test]
fn malformed_requests_reject_at_admission() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = kind;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    for req in [
        scalebits::serve::GenRequest::new(vec![]),
        scalebits::serve::GenRequest::new(vec![-1]),
        scalebits::serve::GenRequest::new(vec![m.config.vocab as i32]),
        scalebits::serve::GenRequest::new(vec![0]).max_new_tokens(0),
    ] {
        let mut t = server.submit_request(req).unwrap();
        let o = t.wait().unwrap();
        assert!(
            matches!(o.finish, scalebits::serve::Finish::Rejected(_)),
            "expected rejection, got {:?}",
            o.finish
        );
        assert!(o.tokens.is_empty());
    }
    let rep = server.shutdown().unwrap();
    assert_eq!(rep.total.rejected, 4);
    assert_eq!(rep.total.served, 0, "no worker may ever see a rejected request");
}

/// THE acceptance property of iteration-level continuous batching: on
/// the interpreter backend, decoding many interleaved sequences
/// through the shared step batches produces bitwise-identical tokens
/// to decoding each sequence alone, one at a time (the kernel module's
/// accumulation-order contract makes batch rows independent).
#[test]
fn continuous_batched_decode_matches_sequential_decode_bitwise() {
    // Forced interpreter over the synthetic artifacts (even when PJRT
    // artifacts exist): bitwise determinism is the interp contract.
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [2, 4, 8][i % 3];
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let (n, max_new) = (6usize, 6usize); // n > compiled batch: admission churns

    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
    cfg.backend = BackendKind::Interp;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let mut tickets = Vec::new();
    for i in 0..n {
        tickets.push(
            server
                .submit_request(
                    scalebits::serve::GenRequest::new(stream.tokens[i * 17..i * 17 + seq].to_vec())
                        .max_new_tokens(max_new),
                )
                .unwrap(),
        );
    }
    let mut served = Vec::new();
    for t in &mut tickets {
        let o = t.wait().unwrap();
        assert_eq!(o.finish, scalebits::serve::Finish::Completed);
        served.push(o.tokens.clone());
    }
    server.shutdown().unwrap();

    // Sequential reference: the same model state, one sequence per
    // step batch, appending each sampled token manually. Serve workers
    // default to f32 activations, so the reference runs f32 too —
    // like-for-like bitwise (cross-precision token parity has its own
    // test below).
    let session =
        Session::open_with(BackendKind::Interp, &dir, &["qpredict"], &alloc.grids(&index))
            .unwrap();
    session.set_activations(ActPrecision::F32).unwrap();
    for i in 0..n {
        let mut toks = stream.tokens[i * 17..i * 17 + seq].to_vec();
        let mut generated = Vec::new();
        for _ in 0..max_new {
            let next = session.decode_step("qpredict", &[toks.as_slice()]).unwrap()[0];
            toks.push(next);
            generated.push(next);
        }
        assert_eq!(
            served[i], generated,
            "request {i}: continuous-batched decode diverged from sequential decode"
        );
    }
}

/// The f32 serving tolerance gate, end-to-end through the decode loop:
/// the same autoregressive decode sweep run with f32 activations (the
/// serve workers' default — SIMD kernels) and with f64 activations
/// (bitwise golden parity) must emit IDENTICAL token IDs at every step,
/// and the final-window logits must stay within a small relative
/// envelope. This is the acceptance contract behind `--activations f32`.
#[test]
fn f32_serving_decode_sweep_matches_f64_token_for_token() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [1, 2, 3, 4, 8, 16][i % 6]; // every SIMD decode family + FP + generic
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let grids = alloc.grids(&index);
    let execs: &[&str] = &["qpredict", "qlogits"];
    let s64 = Session::open_with(BackendKind::Interp, &dir, execs, &grids).unwrap();
    assert_eq!(s64.backend().activations(), ActPrecision::F64, "f64 must stay the default");
    let s32 = Session::open_with(BackendKind::Interp, &dir, execs, &grids).unwrap();
    s32.set_activations(ActPrecision::F32).unwrap();

    let batch = m.exec("qlogits").unwrap().batch;
    let vocab = m.config.vocab;
    let max_new = 6usize;
    for i in 0..4usize {
        let prompt = stream.tokens[i * 29..i * 29 + seq].to_vec();
        let mut toks = prompt.clone();
        for step in 0..max_new {
            let n64 = s64.decode_step("qpredict", &[toks.as_slice()]).unwrap()[0];
            let n32 = s32.decode_step("qpredict", &[toks.as_slice()]).unwrap()[0];
            assert_eq!(
                n32, n64,
                "prompt {i} step {step}: f32 serving emitted a different token"
            );
            // the logits path must agree with the argmax fast path
            let l32 = s32.decode_step("qlogits", &[toks.as_slice()]).unwrap()[0];
            assert_eq!(l32, n32, "prompt {i} step {step}: qlogits/qpredict argmax mismatch");
            toks.push(n64);
        }
        // bounded logit divergence on the final window (all batch rows)
        let (step_toks, _) = scalebits::runtime::session::assemble_step(
            &[toks.as_slice()],
            batch,
            seq,
        );
        let l64 = s64.run("qlogits", &step_toks).unwrap()[0].to_vec_f32().unwrap();
        let l32 = s32.run("qlogits", &step_toks).unwrap()[0].to_vec_f32().unwrap();
        assert_eq!(l32.len(), batch * seq * vocab);
        for (j, (&a, &b)) in l32.iter().zip(l64.iter()).enumerate() {
            let tol = 1e-3 + 1e-3 * (b.abs() as f64);
            assert!(
                ((a - b) as f64).abs() <= tol,
                "prompt {i} logit {j}: f32 {a} vs f64 {b} exceeds tolerance {tol}"
            );
        }
    }
}

/// The int8 serving tolerance gate, end-to-end through the decode loop
/// (the acceptance contract behind `--activations int8`, anchored one
/// rung down the f32-vs-f64 ladder): the same decode sweep run with
/// f32 activations (the serving reference) and with int8 activations
/// must emit IDENTICAL token IDs at every decisively-resolved step —
/// wherever the f32 logit margin (top1 − top2) exceeds twice the
/// measured int8 logit error. A sub-margin argmax is decided by bits
/// no 8-bit representation promises to preserve, so requiring parity
/// there would test the synth weights, not the kernel. Both sessions
/// are teacher-forced along the f32 trajectory so one sub-margin step
/// cannot cascade into comparing different windows. The per-step
/// logits must stay inside the documented int8 envelope, and switching
/// the int8 session back to F32 must restore bitwise-f32 serving.
/// Under SCALEBITS_INT8=off the int8 session is demoted to the f32
/// path and every assert holds bitwise-trivially.
#[test]
fn int8_serving_decode_sweep_matches_f32_token_for_token() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [1, 2, 3, 4, 8, 16][i % 6]; // every decode family + FP + generic
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let grids = alloc.grids(&index);
    let execs: &[&str] = &["qpredict", "qlogits"];
    let s32 = Session::open_with(BackendKind::Interp, &dir, execs, &grids).unwrap();
    s32.set_activations(ActPrecision::F32).unwrap();
    let s8 = Session::open_with(BackendKind::Interp, &dir, execs, &grids).unwrap();
    s8.set_activations(ActPrecision::Int8).unwrap();

    let batch = m.exec("qlogits").unwrap().batch;
    let vocab = m.config.vocab;
    let max_new = 6usize;
    for i in 0..4usize {
        let prompt = stream.tokens[i * 23..i * 23 + seq].to_vec();
        let mut toks = prompt.clone();
        for step in 0..max_new {
            let n32 = s32.decode_step("qpredict", &[toks.as_slice()]).unwrap()[0];
            let n8 = s8.decode_step("qpredict", &[toks.as_slice()]).unwrap()[0];
            // the step's emit-row logits on both paths, for the margin
            let (step_toks, pos) =
                scalebits::runtime::session::assemble_step(&[toks.as_slice()], batch, seq);
            let l32 = s32.run("qlogits", &step_toks).unwrap()[0].to_vec_f32().unwrap();
            let l8 = s8.run("qlogits", &step_toks).unwrap()[0].to_vec_f32().unwrap();
            let r32 = &l32[pos[0] * vocab..(pos[0] + 1) * vocab];
            let r8 = &l8[pos[0] * vocab..(pos[0] + 1) * vocab];
            let mut err = 0.0f32;
            for j in 0..vocab {
                err = err.max((r8[j] - r32[j]).abs());
                let tol = 1e-1 + 1e-1 * (r32[j].abs() as f64);
                assert!(
                    ((r8[j] - r32[j]) as f64).abs() <= tol,
                    "prompt {i} step {step} logit {j}: int8 {} vs f32 {} exceeds \
                     tolerance {tol}",
                    r8[j],
                    r32[j]
                );
            }
            let mut margin = f32::INFINITY;
            for j in 0..vocab {
                if j as i32 != n32 {
                    margin = margin.min(r32[n32 as usize] - r32[j]);
                }
            }
            if margin > 2.0 * err {
                assert_eq!(
                    n8, n32,
                    "prompt {i} step {step}: int8 flipped a decisively-resolved token \
                     (margin {margin:.3e}, int8 err {err:.3e})"
                );
            }
            // teacher-force the f32 trajectory on both sessions
            toks.push(n32);
        }
    }

    // switching back restores the bitwise-f32 serving path
    s8.set_activations(ActPrecision::F32).unwrap();
    let prompt = stream.tokens[..seq].to_vec();
    let (step_toks, _) =
        scalebits::runtime::session::assemble_step(&[prompt.as_slice()], batch, seq);
    let again32 = s32.run("qlogits", &step_toks).unwrap()[0].to_vec_f32().unwrap();
    let again8 = s8.run("qlogits", &step_toks).unwrap()[0].to_vec_f32().unwrap();
    assert_eq!(again8, again32, "F32 restore after an int8 sweep must be bitwise-f32");
}

/// Sequential reference: one sequence per step batch, full prompt fed
/// whole, appending each sampled token manually. Chunked prefill, the
/// virtual live set and preemption must all reproduce this bitwise.
fn sequential_decode(session: &Session, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let mut toks = prompt.to_vec();
    let mut generated = Vec::new();
    for _ in 0..max_new {
        let next = session.decode_step("qpredict", &[toks.as_slice()]).unwrap()[0];
        toks.push(next);
        generated.push(next);
    }
    generated
}

/// THE scheduler acceptance property: decoding through chunked
/// prefill, a virtual live set beyond the compiled batch, AND forced
/// preemption produces bitwise-identical tokens to decoding each
/// sequence alone — for every (prefill_chunk, max_live) combination in
/// the sweep. Eviction is forced by saturating the live set with
/// low-priority generations (first token observed, so they are
/// genuinely live) and then submitting high-priority requests.
#[test]
fn chunked_prefill_and_virtual_live_set_match_sequential_decode_bitwise() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [2, 4, 8][i % 3];
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let batch = m
        .exec(if m.executables.contains_key("qpredict") { "qpredict" } else { "qlogits" })
        .unwrap()
        .batch;
    let max_new = 6usize;
    // Low-priority saturators: EQUAL-length prompts so they prefill in
    // lockstep and are all mid-generation together when the
    // high-priority phase arrives (a mixed-length low set would let
    // the short ones complete while a long one still prefills,
    // de-saturating the live set and defeating forced preemption).
    let low_prompts: Vec<Vec<i32>> =
        (0..3 * batch + 1).map(|i| stream.tokens[i * 23..i * 23 + seq].to_vec()).collect();
    // High-priority arrivals carry the mixed prompt lengths — several
    // LONGER than the window, so prefill really spans iterations (and
    // rows, in whole-prompt mode).
    let high_prompts: Vec<Vec<i32>> = [seq, 2 * seq + 5, seq / 2, seq + 9]
        .iter()
        .enumerate()
        .map(|(i, &len)| stream.tokens[400 + i * 80..400 + i * 80 + len].to_vec())
        .collect();
    let session =
        Session::open_with(BackendKind::Interp, &dir, &["qpredict"], &alloc.grids(&index))
            .unwrap();
    // match the serve workers' default precision (f32 SIMD serving)
    session.set_activations(ActPrecision::F32).unwrap();
    let low_ref: Vec<Vec<i32>> =
        low_prompts.iter().map(|p| sequential_decode(&session, p, max_new)).collect();
    let high_ref: Vec<Vec<i32>> =
        high_prompts.iter().map(|p| sequential_decode(&session, p, max_new)).collect();

    for &chunk in &[1usize, 8, 0] {
        // 0 = whole-prompt
        for &max_live in &[batch, 2 * batch, 3 * batch + 1] {
            let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
            cfg.backend = BackendKind::Interp;
            cfg.prefill_chunk = chunk;
            cfg.max_live = max_live;
            // Static ranks: a slow CI machine must not age the Lows to
            // High and defeat the forced preemption below.
            cfg.aging = std::time::Duration::from_secs(600);
            let mut server = scalebits::serve::Router::start(cfg).unwrap();
            // Phase 1: saturate the live set with low-priority work...
            let n_low = max_live;
            let mut lows = Vec::new();
            for p in low_prompts.iter().take(n_low) {
                lows.push(
                    server
                        .submit_request(
                            scalebits::serve::GenRequest::new(p.clone())
                                .max_new_tokens(max_new)
                                .priority(scalebits::serve::Priority::Low),
                        )
                        .unwrap(),
                );
            }
            // ...observed live: each has emitted its first token, and
            // owes max_new - 1 more iterations.
            for t in lows.iter_mut() {
                assert!(t.recv_token().unwrap().is_some());
            }
            // Phase 2: high-priority arrivals must preempt.
            let mut highs = Vec::new();
            for p in &high_prompts {
                highs.push(
                    server
                        .submit_request(
                            scalebits::serve::GenRequest::new(p.clone())
                                .max_new_tokens(max_new)
                                .priority(scalebits::serve::Priority::High),
                        )
                        .unwrap(),
                );
            }
            let mut low_served = Vec::with_capacity(n_low);
            for t in lows.iter_mut() {
                let o = t.wait().unwrap();
                assert_eq!(o.finish, scalebits::serve::Finish::Completed);
                low_served.push(o.tokens.clone());
            }
            let mut high_served = Vec::with_capacity(high_prompts.len());
            for t in highs.iter_mut() {
                let o = t.wait().unwrap();
                assert_eq!(o.finish, scalebits::serve::Finish::Completed);
                high_served.push(o.tokens.clone());
            }
            let rep = server.shutdown().unwrap();
            for (i, s) in low_served.iter().enumerate() {
                assert_eq!(
                    s, &low_ref[i],
                    "chunk={chunk} max_live={max_live} low {i}: \
                     scheduled decode diverged from sequential decode"
                );
            }
            for (i, s) in high_served.iter().enumerate() {
                assert_eq!(
                    s, &high_ref[i],
                    "chunk={chunk} max_live={max_live} high {i}: \
                     scheduled decode diverged from sequential decode"
                );
            }
            assert!(
                rep.total.preempted >= 1,
                "chunk={chunk} max_live={max_live}: high-priority load over a \
                 saturated live set must preempt"
            );
            if chunk != 0 {
                assert!(
                    rep.total.prefill_rows > 0,
                    "chunk={chunk}: chunked prefill must feed slices"
                );
            }
            if max_live > batch {
                // phase 1 holds max_live > batch sequences live, so at
                // least one iteration must have dispatched several
                // fixed-size step batches
                assert!(
                    rep.total.batches > rep.total.iterations,
                    "virtual live set beyond the compiled batch must time-slice \
                     over multiple step batches per iteration ({} batches, {} iterations)",
                    rep.total.batches,
                    rep.total.iterations
                );
            }
        }
    }
}

/// Preemption round-trip: a sequence evicted mid-generation (and, with
/// chunking, mid-PREFILL) must resume from its kept state and produce
/// exactly the tokens an uninterrupted run produces.
#[test]
fn preempted_sequence_resumes_with_identical_tokens() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [2, 4, 8][i % 3];
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let batch = m
        .exec(if m.executables.contains_key("qpredict") { "qpredict" } else { "qlogits" })
        .unwrap()
        .batch;
    let session =
        Session::open_with(BackendKind::Interp, &dir, &["qpredict"], &alloc.grids(&index))
            .unwrap();
    // match the serve workers' default precision (f32 SIMD serving)
    session.set_activations(ActPrecision::F32).unwrap();
    let max_new = 8usize;
    let prompts: Vec<Vec<i32>> =
        (0..batch).map(|i| stream.tokens[i * 31..i * 31 + seq].to_vec()).collect();
    let reference: Vec<Vec<i32>> =
        prompts.iter().map(|p| sequential_decode(&session, p, max_new)).collect();

    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
    cfg.backend = BackendKind::Interp;
    cfg.prefill_chunk = 4;
    cfg.aging = std::time::Duration::from_secs(600); // static ranks
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    // Fill every live slot with low-priority generations and observe
    // their first tokens (they are decoding, not queued).
    let mut lows = Vec::new();
    for p in &prompts {
        lows.push(
            server
                .submit_request(
                    scalebits::serve::GenRequest::new(p.clone())
                        .max_new_tokens(max_new)
                        .priority(scalebits::serve::Priority::Low),
                )
                .unwrap(),
        );
    }
    for t in lows.iter_mut() {
        assert!(t.recv_token().unwrap().is_some());
    }
    // High-priority burst: evicts the lows mid-generation.
    let mut highs = Vec::new();
    for p in &prompts {
        highs.push(
            server
                .submit_request(
                    scalebits::serve::GenRequest::new(p.clone())
                        .max_new_tokens(2)
                        .priority(scalebits::serve::Priority::High),
                )
                .unwrap(),
        );
    }
    for t in highs.iter_mut() {
        assert_eq!(t.wait().unwrap().finish, scalebits::serve::Finish::Completed);
    }
    for (i, t) in lows.iter_mut().enumerate() {
        let o = t.wait().unwrap();
        assert_eq!(o.finish, scalebits::serve::Finish::Completed);
        assert_eq!(
            o.tokens, reference[i],
            "request {i}: an evicted-and-resumed sequence must decode \
             exactly as an uninterrupted one"
        );
    }
    let rep = server.shutdown().unwrap();
    assert!(rep.total.preempted >= 1, "the high-priority burst must have evicted");
}

/// Chunked prefill removes prompt head-of-line blocking: short
/// requests admitted behind a LONG prompt stream tokens and complete
/// while the long prompt is still prefilling.
#[test]
fn long_prompt_chunked_prefill_does_not_block_short_decodes() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let mut cfg =
        scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = BackendKind::Interp;
    // an 8*seq prompt at chunk 2 needs 4*seq (~128) prefill iterations,
    // while the shorts finish in ~seq/2 + 19 — a margin wide enough
    // that a descheduled test thread cannot flake the ordering check
    cfg.prefill_chunk = 2;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let mut warm = server.submit_warmup(stream.tokens[..seq].to_vec()).unwrap();
    warm.wait().unwrap();

    let mut long = server
        .submit_request(
            scalebits::serve::GenRequest::new(stream.tokens[..8 * seq].to_vec())
                .max_new_tokens(2),
        )
        .unwrap();
    let mut shorts = Vec::new();
    for i in 1..=3 {
        shorts.push(
            server
                .submit_request(
                    scalebits::serve::GenRequest::new(
                        stream.tokens[i * 40..i * 40 + seq].to_vec(),
                    )
                    .max_new_tokens(3),
                )
                .unwrap(),
        );
    }
    for t in shorts.iter_mut() {
        let o = t.wait().unwrap();
        assert_eq!(o.finish, scalebits::serve::Finish::Completed);
        assert_eq!(o.tokens.len(), 3);
    }
    assert!(
        long.poll().unwrap().is_none(),
        "the long prompt must still be prefilling after every short request completed"
    );
    let o = long.wait().unwrap();
    assert_eq!(o.finish, scalebits::serve::Finish::Completed);
    assert_eq!(o.tokens.len(), 2);
    let rep = server.shutdown().unwrap();
    assert!(rep.total.prefill_rows as usize >= 4 * seq, "chunked slices must be counted");
    assert_eq!(rep.total.prefill_tokens, 8 * seq as u64 + 3 * seq as u64);
}

/// Trace replay (ROADMAP item): every recorded arrival is submitted
/// and lands under exactly one terminal reason — the report accounts
/// for the full trace, bursts and long prompts included.
#[test]
fn trace_replay_accounts_every_entry() {
    let trace_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("data")
        .join("bursty_trace.json");
    let entries = scalebits::serve::load_trace(&trace_path).unwrap();
    assert!(entries.len() >= 16, "example trace should be a real burst set");
    let expected_tokens: u64 = entries.iter().map(|e| e.max_new_tokens as u64).sum();

    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let mut cfg =
        scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = BackendKind::Interp;
    cfg.workers = 2;
    cfg.prefill_chunk = m.config.seq_len; // long trace prompts prefill chunked
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let spec = scalebits::serve::WorkloadSpec::new(m.config.seq_len, entries.len(), 1.0, 3)
        .trace(entries.clone());
    let wl = scalebits::serve::run_workload(&mut server, &stream, &spec).unwrap();
    server.shutdown().unwrap();

    let accounted = wl.completed + wl.cancelled + wl.deadline_exceeded + wl.rejected;
    assert_eq!(accounted, entries.len() as u64, "every trace entry must be accounted");
    assert_eq!(wl.completed, entries.len() as u64, "no deadlines: all must complete");
    assert_eq!(wl.decode_tokens, expected_tokens, "each entry decodes its own budget");
    assert!(
        !wl.ttft_long.is_empty(),
        "the bursty trace carries long prompts; their TTFT must be classed long"
    );
}

/// THE acceptance sweep for incremental KV decode state + the radix
/// prefix cache: a shared-prefix multi-turn request mix decoded under
/// every {KV on/off} x {cache off/on/eviction-under-pressure}
/// combination emits BITWISE-identical tokens to sequential decode,
/// and the `prefill_tokens + prefill_tokens_saved == sum(prompt_len)`
/// accounting identity holds exactly — with the big-budget cache
/// hitting the exact block-aligned depths.
#[test]
fn shared_prefix_kv_and_cache_sweep_matches_sequential_decode_bitwise() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [2, 4, 8][i % 3];
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let max_new = 4usize;
    let b = 8usize; // cache block (tokens)
    // Multi-turn template A (each prompt extends the previous one
    // exactly; the last one outgrows seq_len, exercising the slid
    // window's permanent KV fallback), a second template B, then a
    // repeat of an A turn (a pure cache hit).
    let prompts: Vec<Vec<i32>> = vec![
        stream.tokens[..2 * b].to_vec(),
        stream.tokens[..3 * b].to_vec(),
        stream.tokens[..4 * b].to_vec(),
        stream.tokens[..5 * b].to_vec(),
        stream.tokens[100..100 + 3 * b].to_vec(),
        stream.tokens[..3 * b].to_vec(),
    ];
    let total_prompt: u64 = prompts.iter().map(|p| p.len() as u64).sum();
    let session =
        Session::open_with(BackendKind::Interp, &dir, &["qpredict"], &alloc.grids(&index))
            .unwrap();
    session.set_activations(ActPrecision::F32).unwrap();
    let reference: Vec<Vec<i32>> =
        prompts.iter().map(|p| sequential_decode(&session, p, max_new)).collect();

    // node cost = block * (kv_token_bytes + 4); a 2-node budget forces
    // eviction under this mix (each template inserts 2-5 blocks)
    let kv_token_bytes = m.config.n_layers * 2 * m.config.d_model * 4;
    let two_nodes = 2 * b * (kv_token_bytes + 4);
    for kv in [true, false] {
        for (mode, cache_bytes) in [("off", 0usize), ("on", 1 << 20), ("tiny", two_nodes)] {
            let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
            cfg.backend = BackendKind::Interp;
            cfg.kv = kv;
            cfg.cache_bytes = cache_bytes;
            cfg.cache_block = b;
            cfg.prefill_chunk = 4;
            let mut server = scalebits::serve::Router::start(cfg).unwrap();
            // Sequential submit+wait: each prompt's blocks are cached
            // (and evicted) before the next lookup — deterministic
            // depths, so the accounting asserts below can be exact.
            let mut served = Vec::new();
            for p in &prompts {
                let mut t = server
                    .submit_request(
                        scalebits::serve::GenRequest::new(p.clone()).max_new_tokens(max_new),
                    )
                    .unwrap();
                let o = t.wait().unwrap();
                assert_eq!(o.finish, scalebits::serve::Finish::Completed);
                served.push(o.tokens.clone());
            }
            let rep = server.shutdown().unwrap();
            for (i, s) in served.iter().enumerate() {
                assert_eq!(
                    s, &reference[i],
                    "kv={kv} cache={mode} prompt {i}: decode diverged from sequential"
                );
            }
            let t = &rep.total;
            assert_eq!(
                t.prefill_tokens + t.prefill_tokens_saved,
                total_prompt,
                "kv={kv} cache={mode}: prefill accounting identity broke"
            );
            match mode {
                "off" => {
                    assert_eq!(t.prefill_tokens_saved, 0);
                    assert_eq!((t.cache_hits, t.cache_misses, t.cache_evictions), (0, 0, 0));
                }
                "on" => {
                    // exact block-aligned depths: turn 2 matches 2
                    // blocks, turn 3 matches 3 (max depth is always
                    // prompt_len-1: the emit row must feed a token),
                    // turn 4 matches 4, the repeat matches 2 again
                    let want = (2 + 3 + 4 + 2) as u64 * b as u64;
                    assert_eq!(t.prefill_tokens_saved, want, "kv={kv}: wrong saved depth");
                    assert_eq!((t.cache_hits, t.cache_misses), (4, 2));
                    assert_eq!(t.cache_evictions, 0, "1 MiB budget must not evict here");
                }
                _ => {
                    assert!(
                        t.cache_evictions > 0,
                        "kv={kv}: a 2-node budget must evict under this mix"
                    );
                }
            }
        }
    }
}

/// The shared-template trace generator through the full workload
/// driver, cache-aware placement on: the accounting identity holds
/// exactly across workers and every recorded request gets exactly one
/// cache lookup — under CONCURRENT (not sequential) arrivals.
#[test]
fn shared_template_workload_keeps_exact_prefill_accounting() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let (templates, turns, template_len, turn_len) = (2usize, 3usize, 16usize, 8usize);
    let trace = scalebits::serve::shared_template_trace(
        templates,
        turns,
        500.0,
        template_len,
        turn_len,
        2,
        11,
    );
    let total_prompt: u64 = trace.iter().map(|e| e.prompt_len as u64).sum();
    assert_eq!(total_prompt, 144, "2 templates x (16+24+32)");

    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = BackendKind::Interp;
    cfg.workers = 2;
    cfg.cache_bytes = 1 << 20;
    cfg.cache_block = 8;
    cfg.prefill_chunk = 4;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let spec = scalebits::serve::WorkloadSpec::new(m.config.seq_len, trace.len(), 1.0, 5)
        .max_new_tokens(2)
        .trace(trace.clone());
    let wl = scalebits::serve::run_workload(&mut server, &stream, &spec).unwrap();
    let rep = server.shutdown().unwrap();
    assert_eq!(wl.completed, trace.len() as u64);
    let t = &rep.total;
    assert_eq!(
        t.prefill_tokens + t.prefill_tokens_saved,
        total_prompt,
        "identity must hold exactly under concurrent arrivals and placement"
    );
    assert_eq!(
        t.cache_hits + t.cache_misses,
        trace.len() as u64,
        "every recorded request gets exactly one cache lookup (warmups excluded)"
    );
}

/// Cache-aware placement: with per-worker caches, a request repeating
/// an already-served prompt must land on the worker that holds the
/// prefix (longest-prefix-match admission) and skip the matched
/// blocks; a cold prompt falls back to round-robin.
#[test]
fn prefix_placement_routes_repeats_to_the_caching_worker() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let b = 8usize;
    let prompt = stream.tokens[200..200 + 4 * b].to_vec();

    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), BitAlloc::uniform(&index, 4));
    cfg.backend = BackendKind::Interp;
    cfg.workers = 2;
    cfg.cache_bytes = 1 << 20;
    cfg.cache_block = b;
    assert_eq!(cfg.placement, scalebits::serve::Placement::Prefix, "cache-aware by default");
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let first = {
        let mut t = server
            .submit_request(scalebits::serve::GenRequest::new(prompt.clone()).max_new_tokens(2))
            .unwrap();
        t.wait().unwrap().clone()
    };
    let second = {
        let mut t = server
            .submit_request(scalebits::serve::GenRequest::new(prompt.clone()).max_new_tokens(2))
            .unwrap();
        t.wait().unwrap().clone()
    };
    let rep = server.shutdown().unwrap();
    assert_eq!(first.finish, scalebits::serve::Finish::Completed);
    assert_eq!(second.finish, scalebits::serve::Finish::Completed);
    assert_eq!(first.tokens, second.tokens, "identical prompts decode identically");
    assert_eq!(
        second.worker, first.worker,
        "the repeat must home on the worker holding the cached prefix"
    );
    // 4*b prompt, emit needs a token: the repeat matches 3 blocks
    assert_eq!(rep.total.prefill_tokens_saved, 3 * b as u64);
    assert_eq!((rep.total.cache_hits, rep.total.cache_misses), (1, 1));
}

/// The acceptance check for grid residency: once a Session is built,
/// the serve path's only host→device transfer per batch is the token
/// batch itself (weights AND bit grids stay resident). The interpreter
/// keeps the identical ledger, so this runs on both backends.
#[test]
fn serve_path_uploads_tokens_only() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let alloc = BitAlloc::uniform(&index, 4);
    let session =
        Session::open_with(kind, &dir, &["qloss"], &alloc.grids(&index)).unwrap();
    let batch = session.backend().batch_of("qloss").unwrap();
    let seq = session.manifest().config.seq_len;
    let stream =
        scalebits::calib::TokenStream::from_manifest(session.manifest(), "eval").unwrap();
    let tokens: Vec<i32> = stream.tokens[..batch * seq].to_vec();

    session.run("qloss", &tokens).unwrap(); // warm
    session.backend().reset_transfer_stats();
    for _ in 0..3 {
        session.run("qloss", &tokens).unwrap();
    }
    let t = session.backend().transfer_stats();
    assert_eq!(t.uploads, 3, "per-batch transfers must be the token batch only");
    assert_eq!(t.bytes, 3 * (batch * seq * 4) as u64);
}

/// Serving equivalence for the packed-kernel path: `qlogits` at a
/// mixed-precision grid must equal quantizing host-side (the rust RTN
/// mirror) and serving the result at the FP sentinel. On the
/// interpreter the first run goes through the fused packed kernels and
/// the second through FP-passthrough blocks, so this pins the
/// compressed serving path to the dense fake-quant reference —
/// bitwise on interp, f32-tolerance on PJRT.
#[test]
fn packed_serving_qlogits_match_host_fakequant_reference() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qlogits", "qpredict"]).unwrap();
    let mut sampler = p.sampler(23);
    let tokens = sampler.sample(p.batch_of("qlogits").unwrap());
    let mut alloc = BitAlloc::uniform(&p.index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [1, 2, 3, 4, 8, 16][i % 6];
    }
    let grids = alloc.grids(&p.index);
    let quantized =
        p.backend.run_model_host_grids("qlogits", &tokens, &grids, &p.wbufs).unwrap()[0]
            .to_vec_f32()
            .unwrap();

    // host-side fakequant + FP-sentinel serve of the result
    let mut store = p.store.clone();
    for (mi, name) in p.index.mats.iter().enumerate() {
        let grid = &alloc.bits[p.index.mat_range(mi)];
        let wq = fakequant_mat(
            p.store.get(name).unwrap(),
            grid,
            p.index.block_rows,
            p.index.block_cols,
        );
        *store.get_mut(name).unwrap() = wq;
    }
    let bufs = p.backend.upload_weights(&store).unwrap();
    let fp_grids = p.fp_alloc().grids(&p.index);
    let reference =
        p.backend.run_model_host_grids("qlogits", &tokens, &fp_grids, &bufs).unwrap()[0]
            .to_vec_f32()
            .unwrap();

    assert_eq!(quantized.len(), reference.len());
    let mut max_abs = 0.0f32;
    for (a, b) in quantized.iter().zip(&reference) {
        max_abs = max_abs.max((a - b).abs());
    }
    if kind == BackendKind::Interp {
        assert_eq!(quantized, reference, "packed serving path diverged (max abs {max_abs})");
    } else {
        assert!(max_abs < 2e-3, "packed serving path diverged: {max_abs}");
    }

    // qpredict (the serve workers' fast path) must agree with the
    // argmax of the packed logits
    let preds = p.backend.run_model_host_grids("qpredict", &tokens, &grids, &p.wbufs).unwrap()[0]
        .to_vec_i32()
        .unwrap();
    let vocab = p.manifest().config.vocab;
    for (i, row) in quantized.chunks_exact(vocab).enumerate() {
        let mut best = 0usize;
        for (j, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = j;
            }
        }
        assert_eq!(preds[i], best as i32, "position {i}");
    }
}

/// The end-to-end serving round-trip off compressed weights: a router
/// worker serving a mixed-precision allocation must return the same
/// next-token predictions as the host-side dense fake-quant reference.
#[test]
fn server_round_trip_packed_weights_match_dense_reference() {
    let (kind, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [2, 4, 8][i % 3];
    }
    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
    cfg.backend = kind;
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let mut tickets = Vec::new();
    for i in 0..4 {
        tickets
            .push(server.submit(stream.tokens[i * 64..i * 64 + m.config.seq_len].to_vec()).unwrap());
    }
    let served: Vec<i32> =
        tickets.iter_mut().map(|t| t.wait().unwrap().tokens[0]).collect();
    server.shutdown().unwrap();

    // dense reference: qlogits over the same resident state, argmax at
    // the last position of each request window
    let p = Pipeline::load_with(kind, &dir, &["qlogits"]).unwrap();
    let batch = p.batch_of("qlogits").unwrap();
    let seq = m.config.seq_len;
    let vocab = m.config.vocab;
    let grids = alloc.grids(&index);
    for (i, &got) in served.iter().enumerate() {
        let window = &stream.tokens[i * 64..i * 64 + seq];
        let mut tokens = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            tokens.extend_from_slice(window);
        }
        let logits = p.backend.run_model_host_grids("qlogits", &tokens, &grids, &p.wbufs).unwrap()
            [0]
            .to_vec_f32()
            .unwrap();
        let row = &logits[(seq - 1) * vocab..seq * vocab];
        let mut best = 0usize;
        for (v, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = v;
            }
        }
        assert_eq!(got, best as i32, "request {i}: served token diverged from dense reference");
    }
}

// ---------------------------------------------------------------------
// weight store + manifest sanity (both backends)

#[test]
fn manifest_and_weights_consistent() {
    let (_, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let store = WeightStore::load(&m).unwrap();
    assert_eq!(store.order.len(), m.params.len());
    let index = BlockIndex::from_manifest(&m).unwrap();
    assert_eq!(index.n_blocks, m.n_blocks);
    // every quantized matrix tiles exactly
    for name in &m.quantized {
        let p = m.param(name).unwrap();
        assert_eq!(p.rows() % m.config.block_rows, 0);
        assert_eq!(p.cols() % m.config.block_cols, 0);
    }
    // weights are finite and not all zero
    for (name, mat) in store.in_order() {
        assert!(mat.data.iter().all(|x| x.is_finite()), "{name}");
        assert!(mat.sq_frobenius() > 0.0, "{name}");
    }
}

#[test]
fn batch_sampler_stays_in_vocab() {
    let (_, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "calib").unwrap();
    let mut s = BatchSampler::new(stream, m.config.seq_len, 3);
    let b = s.sample(8);
    assert!(b.iter().all(|&t| t >= 0 && (t as usize) < m.config.vocab));
}

// ---------------------------------------------------------------------
// kernel-bench executables numerics (PJRT only)

#[test]
fn mpq_kernel_exec_matches_host_reference() {
    require_pjrt!();
    let m = Manifest::load(&real_artifacts()).unwrap();
    let kb = m.kernel_bench().unwrap();
    let engine = Engine::load(m, &[]).unwrap();
    let exe = engine
        .compile_hlo_file(&engine.manifest.dir.join(&kb.files["mpq"]))
        .unwrap();
    let (mm, n, k) = (kb.m, kb.n, kb.k);
    let (br, bc) = (kb.block_rows, kb.block_cols);
    let mut rng = scalebits::util::rng::Rng::new(5);
    let x: Vec<f32> = (0..mm * k).map(|_| rng.normal_f32()).collect();
    let w = Mat::from_vec(n, k, (0..n * k).map(|_| rng.normal_f32()).collect()).unwrap();
    let bits = vec![4i32; (n / br) * (k / bc)];
    let packed = scalebits::quant::PackedMat::quantize(&w, &bits, br, bc);
    let deq = packed.dequantize();
    // integer codes + scales as the executable wants them
    let nbc = k / bc;
    let mut codes = vec![0i8; n * k];
    for r in 0..n {
        for g in 0..nbc {
            let s = packed.scales[r * nbc + g];
            for c in 0..bc {
                let idx = r * k + g * bc + c;
                codes[idx] = if s > 0.0 { (deq.data[idx] / s).round_ties_even() as i8 } else { 0 };
            }
        }
    }
    let args = vec![
        engine.upload_f32(&x, &[mm, k]).unwrap(),
        engine.upload_i8(&codes, &[n, k]).unwrap(),
        engine.upload_f32(&packed.scales, &[n, nbc]).unwrap(),
        engine.upload_i32(&bits, &[n / br, nbc]).unwrap(),
    ];
    let out = engine.run_raw("mpq", &exe, &args).unwrap();
    // run_raw executions are cost-accounted like every other path
    let stats = Engine::stats(&engine);
    assert_eq!(stats.get("mpq").map(|s| s.calls), Some(1));
    let y = scalebits::runtime::literal_to_vec_f32(&out[0]).unwrap();
    // host reference: x @ deq^T
    for r in 0..4 {
        for c in 0..8 {
            let mut want = 0.0f64;
            for j in 0..k {
                want += x[r * k + j] as f64 * deq.data[c * k + j] as f64;
            }
            let got = y[r * n + c] as f64;
            assert!(
                (got - want).abs() < 1e-2 * want.abs().max(1.0),
                "({r},{c}): {got} vs {want}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// packed model export / load roundtrip (both backends: host-side)

#[test]
fn packfile_roundtrip_bit_exact() {
    let (_, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let store = WeightStore::load(&m).unwrap();
    let mut rng = scalebits::util::rng::Rng::new(21);
    let mut alloc = BitAlloc::uniform(&index, 3);
    for b in alloc.bits.iter_mut() {
        // 1..=8 plus the FP sentinel: full-precision blocks must
        // survive packing as raw f32 (SBITS2), not clamp to 8-bit
        *b = rng.range(1, 10) as i32;
    }
    let path = std::env::temp_dir().join("scalebits_test_model.sbits");
    let n = scalebits::quant::packfile::write_packfile(&path, &m, &index, &store, &alloc)
        .unwrap();
    assert!(n > 0);
    let (store2, alloc2) =
        scalebits::quant::packfile::read_packfile(&path, &m, &index).unwrap();
    assert_eq!(alloc2.bits, alloc.bits);
    for name in &index.mats {
        let mi = index.mat_index(name).unwrap();
        let grid = &alloc.bits[index.mat_range(mi)];
        let want = fakequant_mat(store.get(name).unwrap(), grid, index.block_rows, index.block_cols);
        let got = store2.get(name).unwrap();
        for i in 0..want.data.len() {
            let tol = 2e-3 * want.data[i].abs().max(1e-3);
            assert!(
                (got.data[i] - want.data[i]).abs() <= tol,
                "{name}[{i}]: {} vs {}",
                got.data[i],
                want.data[i]
            );
        }
    }
    // unquantized params round-trip exactly
    for p in &m.params {
        if !p.quantized {
            assert_eq!(store2.get(&p.name).unwrap().data, store.get(&p.name).unwrap().data);
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn packfile_rejects_corrupt_magic() {
    let (_, dir) = setup();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let path = std::env::temp_dir().join("scalebits_bad.sbits");
    std::fs::write(&path, b"NOTSBITSxxxxxxxxxxxx").unwrap();
    assert!(scalebits::quant::packfile::read_packfile(&path, &m, &index).is_err());
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// failure injection: the runtime must reject malformed calls loudly
// (identically on either backend)

#[test]
fn runtime_rejects_bad_shapes() {
    let (kind, dir) = setup();
    let p = Pipeline::load_with(kind, &dir, &["qloss"]).unwrap();
    let alloc = BitAlloc::uniform(&p.index, 3);
    let grids = alloc.grids(&p.index);
    // wrong token count
    let bad_tokens = vec![0i32; 17];
    assert!(p.backend.run_model_host_grids("qloss", &bad_tokens, &grids, &p.wbufs).is_err());
    // wrong grid count
    let mut sampler = p.sampler(1);
    let tokens = sampler.sample(p.batch_of("qloss").unwrap());
    assert!(p
        .backend
        .run_model_host_grids("qloss", &tokens, &grids[..grids.len() - 1], &p.wbufs)
        .is_err());
    // wrong grid shape
    let mut bad_grids = grids.clone();
    bad_grids[0].pop();
    assert!(p.backend.run_model_host_grids("qloss", &tokens, &bad_grids, &p.wbufs).is_err());
    // unknown executable
    assert!(p.backend.run_model_host_grids("nonexistent", &tokens, &grids, &p.wbufs).is_err());
}

// ---------------------------------------------------------------------
// self-speculative decoding (draft-and-verify) + cache-aware preemption

/// `SCALEBITS_SPEC=off` / `=0` kill-switch (the ci.sh second pass):
/// bitwise identity must hold either way, but the drafted/accepted
/// counter asserts flip — drafting requested and switched off must
/// count exactly zero.
fn spec_disabled_by_env() -> bool {
    !scalebits::util::env::spec_on()
}

/// THE acceptance sweep for self-speculative decoding: for every
/// spec_k {2,4,8} x {KV on, off} combination — under a saturated live
/// set with a high-priority burst forcing preemption — the served
/// tokens are BITWISE-identical to plain (non-speculative) sequential
/// decode, and the drafted counter proves speculation actually ran.
/// Greedy verification makes this an identity, not a tolerance: a
/// verify round emits exactly the tokens plain decode would emit, the
/// draft allocation only decides how many arrive per round.
#[test]
fn speculative_decode_sweep_matches_sequential_decode_bitwise() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [2, 4, 8][i % 3];
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let batch = m
        .exec(if m.executables.contains_key("qpredict") { "qpredict" } else { "qlogits" })
        .unwrap()
        .batch;
    let max_new = 6usize;
    // Short prompts leave window headroom: drafting needs an unslid,
    // unfilled window (pos0 == 0, window < seq_len). Equal lengths keep
    // the saturators in lockstep so the burst genuinely preempts.
    let low_prompts: Vec<Vec<i32>> =
        (0..batch).map(|i| stream.tokens[i * 23..i * 23 + seq / 2].to_vec()).collect();
    // One longer-than-seq prompt rides along: its slid window is
    // ineligible for drafting and must fall back to plain decode.
    let high_prompts: Vec<Vec<i32>> = [seq / 2, 2 * seq + 5, seq / 2 + 3]
        .iter()
        .enumerate()
        .map(|(i, &len)| stream.tokens[400 + i * 80..400 + i * 80 + len].to_vec())
        .collect();
    let session =
        Session::open_with(BackendKind::Interp, &dir, &["qpredict"], &alloc.grids(&index))
            .unwrap();
    // match the serve workers' default precision (f32 SIMD serving)
    session.set_activations(ActPrecision::F32).unwrap();
    let low_ref: Vec<Vec<i32>> =
        low_prompts.iter().map(|p| sequential_decode(&session, p, max_new)).collect();
    let high_ref: Vec<Vec<i32>> =
        high_prompts.iter().map(|p| sequential_decode(&session, p, max_new)).collect();

    for &spec_k in &[2usize, 4, 8] {
        for &kv in &[true, false] {
            let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
            cfg.backend = BackendKind::Interp;
            cfg.kv = kv;
            cfg.spec_k = spec_k;
            cfg.prefill_chunk = 4;
            cfg.max_live = batch; // saturable: the burst below must preempt
            cfg.aging = std::time::Duration::from_secs(600); // static ranks
            let mut server = scalebits::serve::Router::start(cfg).unwrap();
            // Phase 1: saturate the live set with low-priority work,
            // observed live (first token received).
            let mut lows = Vec::new();
            for p in &low_prompts {
                lows.push(
                    server
                        .submit_request(
                            scalebits::serve::GenRequest::new(p.clone())
                                .max_new_tokens(max_new)
                                .priority(scalebits::serve::Priority::Low),
                        )
                        .unwrap(),
                );
            }
            for t in lows.iter_mut() {
                assert!(t.recv_token().unwrap().is_some());
            }
            // Phase 2: high-priority arrivals must preempt mid-draft.
            let mut highs = Vec::new();
            for p in &high_prompts {
                highs.push(
                    server
                        .submit_request(
                            scalebits::serve::GenRequest::new(p.clone())
                                .max_new_tokens(max_new)
                                .priority(scalebits::serve::Priority::High),
                        )
                        .unwrap(),
                );
            }
            let mut low_served = Vec::with_capacity(low_prompts.len());
            for t in lows.iter_mut() {
                let o = t.wait().unwrap();
                assert_eq!(o.finish, scalebits::serve::Finish::Completed);
                low_served.push(o.tokens.clone());
            }
            let mut high_served = Vec::with_capacity(high_prompts.len());
            for t in highs.iter_mut() {
                let o = t.wait().unwrap();
                assert_eq!(o.finish, scalebits::serve::Finish::Completed);
                high_served.push(o.tokens.clone());
            }
            let rep = server.shutdown().unwrap();
            for (i, s) in low_served.iter().enumerate() {
                assert_eq!(
                    s, &low_ref[i],
                    "spec_k={spec_k} kv={kv} low {i}: speculative decode \
                     diverged from sequential decode"
                );
            }
            for (i, s) in high_served.iter().enumerate() {
                assert_eq!(
                    s, &high_ref[i],
                    "spec_k={spec_k} kv={kv} high {i}: speculative decode \
                     diverged from sequential decode"
                );
            }
            let t = &rep.total;
            if spec_disabled_by_env() {
                assert_eq!(
                    t.spec_drafted, 0,
                    "spec_k={spec_k} kv={kv}: SCALEBITS_SPEC=off must kill drafting"
                );
            } else {
                assert!(
                    t.spec_drafted > 0,
                    "spec_k={spec_k} kv={kv}: eligible short-prompt decodes must draft"
                );
            }
            assert!(
                t.spec_accepted <= t.spec_drafted,
                "spec_k={spec_k} kv={kv}: accepted ({}) cannot exceed drafted ({})",
                t.spec_accepted,
                t.spec_drafted
            );
            assert!(
                t.preempted >= 1,
                "spec_k={spec_k} kv={kv}: high-priority load over a saturated \
                 live set must preempt"
            );
        }
    }
}

/// Degenerate-draft control: serving the uniform 2-bit allocation with
/// `spec_bits = 2` makes draft and target the SAME quantized model, so
/// every greedy draft token must verify — drafted == accepted and the
/// accept-rate is exactly 1.0, no tolerance. A rider request opting
/// out via `GenRequest::spec_k(0)` must still decode bitwise.
#[test]
fn degenerate_draft_equal_allocations_accept_every_token() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let alloc = BitAlloc::uniform(&index, 2);
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let seq = m.config.seq_len;
    let max_new = 6usize;
    let prompts: Vec<Vec<i32>> =
        (0..4).map(|i| stream.tokens[i * 37..i * 37 + seq / 2].to_vec()).collect();
    let session =
        Session::open_with(BackendKind::Interp, &dir, &["qpredict"], &alloc.grids(&index))
            .unwrap();
    session.set_activations(ActPrecision::F32).unwrap();
    let reference: Vec<Vec<i32>> =
        prompts.iter().map(|p| sequential_decode(&session, p, max_new)).collect();

    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
    cfg.backend = BackendKind::Interp;
    cfg.spec_k = 4;
    cfg.spec_bits = 2; // == the served allocation: the degenerate pair
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    let mut tickets = Vec::new();
    for p in &prompts {
        tickets.push(
            server
                .submit_request(
                    scalebits::serve::GenRequest::new(p.clone()).max_new_tokens(max_new),
                )
                .unwrap(),
        );
    }
    // the opt-out rider: per-request spec_k = 0 disables drafting for
    // this sequence only; its tokens must match prompt 0's reference
    let mut rider = server
        .submit_request(
            scalebits::serve::GenRequest::new(prompts[0].clone())
                .max_new_tokens(max_new)
                .spec_k(0),
        )
        .unwrap();
    for (i, t) in tickets.iter_mut().enumerate() {
        let o = t.wait().unwrap();
        assert_eq!(o.finish, scalebits::serve::Finish::Completed);
        assert_eq!(
            o.tokens, reference[i],
            "prompt {i}: degenerate speculative decode diverged from sequential"
        );
    }
    let ro = rider.wait().unwrap();
    assert_eq!(ro.finish, scalebits::serve::Finish::Completed);
    assert_eq!(ro.tokens, reference[0], "the spec_k(0) opt-out must decode bitwise too");
    let rep = server.shutdown().unwrap();
    let t = &rep.total;
    assert_eq!(
        t.spec_accepted, t.spec_drafted,
        "equal draft/target allocations must accept every drafted token"
    );
    if spec_disabled_by_env() {
        assert_eq!(t.spec_drafted, 0, "SCALEBITS_SPEC=off must kill drafting");
    } else {
        assert!(t.spec_drafted > 0, "the degenerate pair must still draft");
        assert_eq!(t.spec_accept_rate(), 1.0, "accept-rate must be exactly 1.0");
    }
}

/// Cache-aware preemption: a preempted sequence must release its
/// prefix-cache pins while it sits in the pen (and re-pin whatever is
/// still cached on resume), so a tiny `cache_bytes` budget whose every
/// node is pinned by the preempted owner cannot wedge insertion.
/// Observable: after the owner's whole 2-node budget was pinned, a
/// disjoint high-priority prompt's blocks still get cached (a repeat
/// of it HITS), and everything decodes bitwise.
#[test]
fn preempted_sequence_releases_cache_pins_so_eviction_proceeds() {
    let dir = synth_dir().clone();
    let m = Manifest::load(&dir).unwrap();
    let index = BlockIndex::from_manifest(&m).unwrap();
    let mut alloc = BitAlloc::uniform(&index, 4);
    for (i, b) in alloc.bits.iter_mut().enumerate() {
        *b = [2, 4, 8][i % 3];
    }
    let stream = scalebits::calib::TokenStream::from_manifest(&m, "eval").unwrap();
    let b = 8usize; // cache block (tokens)
    let kv_token_bytes = m.config.n_layers * 2 * m.config.d_model * 4;
    let two_nodes = 2 * b * (kv_token_bytes + 4);
    let warm_prompt = stream.tokens[..2 * b].to_vec(); // seeds exactly 2 blocks
    let low_prompt = stream.tokens[..2 * b + 4].to_vec(); // matches (and PINS) both
    let high_prompt = stream.tokens[300..300 + 3 * b].to_vec(); // disjoint: must insert
    let session =
        Session::open_with(BackendKind::Interp, &dir, &["qpredict"], &alloc.grids(&index))
            .unwrap();
    session.set_activations(ActPrecision::F32).unwrap();
    let max_new = 8usize;
    let warm_ref = sequential_decode(&session, &warm_prompt, 2);
    let low_ref = sequential_decode(&session, &low_prompt, max_new);
    let high_ref = sequential_decode(&session, &high_prompt, 2);

    let mut cfg = scalebits::serve::ServeConfig::new(dir.clone(), alloc.clone());
    cfg.backend = BackendKind::Interp;
    cfg.cache_bytes = two_nodes;
    cfg.cache_block = b;
    cfg.prefill_chunk = 4;
    cfg.max_live = 1; // one slot: the high-priority arrival must preempt
    cfg.aging = std::time::Duration::from_secs(600); // static ranks
    let mut server = scalebits::serve::Router::start(cfg).unwrap();
    // Seed the cache: completing this fills the entire 2-node budget
    // with the shared prefix's blocks.
    {
        let mut t = server
            .submit_request(
                scalebits::serve::GenRequest::new(warm_prompt.clone()).max_new_tokens(2),
            )
            .unwrap();
        let o = t.wait().unwrap();
        assert_eq!(o.finish, scalebits::serve::Finish::Completed);
        assert_eq!(o.tokens, warm_ref);
    }
    // The pin owner: its lookup matches both cached nodes (depth 2*b),
    // pinning the WHOLE budget, then it decodes slowly.
    let mut low = server
        .submit_request(
            scalebits::serve::GenRequest::new(low_prompt.clone())
                .max_new_tokens(max_new)
                .priority(scalebits::serve::Priority::Low),
        )
        .unwrap();
    assert!(low.recv_token().unwrap().is_some());
    // Disjoint high-priority arrival: preempts the owner and needs
    // cache nodes of its own — its blocks can only be admitted if the
    // pen walk released the owner's pins.
    let mut high = server
        .submit_request(
            scalebits::serve::GenRequest::new(high_prompt.clone())
                .max_new_tokens(2)
                .priority(scalebits::serve::Priority::High),
        )
        .unwrap();
    let ho = high.wait().unwrap();
    assert_eq!(ho.finish, scalebits::serve::Finish::Completed);
    assert_eq!(ho.tokens, high_ref);
    let lo = low.wait().unwrap();
    assert_eq!(lo.finish, scalebits::serve::Finish::Completed);
    assert_eq!(
        lo.tokens, low_ref,
        "the preempted pin owner must resume and decode bitwise (its pinned \
         blocks were evicted underneath it)"
    );
    // The discriminating probe: a repeat of the disjoint prompt must
    // HIT — its blocks could only have been cached by evicting the
    // preempted owner's released pins.
    let mut rep_t = server
        .submit_request(
            scalebits::serve::GenRequest::new(high_prompt.clone()).max_new_tokens(2),
        )
        .unwrap();
    let po = rep_t.wait().unwrap();
    assert_eq!(po.finish, scalebits::serve::Finish::Completed);
    assert_eq!(po.tokens, high_ref);
    let rep = server.shutdown().unwrap();
    let t = &rep.total;
    assert!(t.preempted >= 1, "the high-priority arrival must preempt the only slot");
    assert!(
        t.cache_evictions > 0,
        "a fully-pinned budget must become evictable once its owner is preempted"
    );
    // warm misses, owner hits (2 blocks), disjoint misses, probe hits
    // (2 blocks: its 3rd is over budget) — the probe's hit is the fix.
    assert_eq!(
        (t.cache_hits, t.cache_misses),
        (2, 2),
        "the disjoint prompt's blocks must have been admitted while the pin \
         owner sat preempted"
    );
    assert_eq!(
        t.prefill_tokens_saved,
        4 * b as u64,
        "owner and probe each skip exactly the 2 cached blocks"
    );
    let total_prompt = (warm_prompt.len() + low_prompt.len() + 2 * high_prompt.len()) as u64;
    assert_eq!(t.prefill_tokens + t.prefill_tokens_saved, total_prompt);
}

#[test]
fn config_presets_parse_and_build_search_configs() {
    for preset in ["ultra_low", "standard", "fast_fixed_grads"] {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("configs")
            .join(format!("{preset}.toml"));
        let doc = scalebits::util::tomlite::TomlDoc::read_file(&path).unwrap();
        let cfg = scalebits::util::tomlite::search_config_from(&doc).unwrap();
        assert!(cfg.budget >= 1.0 && cfg.budget <= 8.0, "{preset}");
        assert!(cfg.bits_min >= 1 && cfg.bits_max <= 8, "{preset}");
    }
}
