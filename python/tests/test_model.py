"""L2 correctness: model graph shapes, quantization plumbing, gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    ce_loss,
    forward,
    graph_arg_specs,
    init_params,
    list_to_params,
    make_graphs,
    params_to_list,
)

CFG = ModelConfig(n_layers=2, seq_len=32)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, (2, CFG.seq_len)), jnp.int32)
    return params, toks


def fp_bits():
    return [jnp.full(CFG.bits_shape(n), 16, jnp.int32)
            for n in CFG.quantized_names()]


def uniform_bits(b):
    return [jnp.full(CFG.bits_shape(n), b, jnp.int32)
            for n in CFG.quantized_names()]


def test_param_registry_roundtrip():
    params = init_params(CFG, jax.random.PRNGKey(1))
    lst = params_to_list(CFG, params)
    back = list_to_params(CFG, lst)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(params[k]))


def test_param_shapes():
    for n in CFG.param_names():
        s = CFG.param_shape(n)
        assert all(d > 0 for d in s)
    for n in CFG.quantized_names():
        r, c = CFG.param_shape(n)
        assert r % CFG.block_rows == 0 and c % CFG.block_cols == 0


def test_n_blocks_consistent():
    total = sum(int(np.prod(CFG.bits_shape(n))) for n in CFG.quantized_names())
    assert CFG.n_blocks() == total
    assert total > 0


def test_forward_shapes(setup):
    params, toks = setup
    logits = forward(CFG, params, toks)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_causal(setup):
    """Changing a future token must not change past logits."""
    params, toks = setup
    logits1 = forward(CFG, params, toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    logits2 = forward(CFG, params, toks2)
    np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                               np.asarray(logits2[:, :-1]), rtol=1e-5, atol=1e-5)


def test_qloss_fp_equals_plain_loss(setup):
    params, toks = setup
    graphs = make_graphs(CFG)
    args = [toks] + fp_bits() + params_to_list(CFG, params)
    qloss = graphs["qloss"](*args)[0]
    plain = ce_loss(forward(CFG, params, toks), toks)
    np.testing.assert_allclose(float(qloss), float(plain), rtol=1e-6)


def test_qloss_degrades_with_fewer_bits(setup):
    params, toks = setup
    graphs = make_graphs(CFG)
    plist = params_to_list(CFG, params)
    losses = {}
    for b in [2, 8, 16]:
        args = [toks] + uniform_bits(b) + plist
        losses[b] = float(graphs["qloss"](*args)[0])
    # 8-bit is near-lossless; 2-bit must hurt (random weights => small
    # margins, so compare against the aggressive end only).
    assert abs(losses[16] - losses[8]) < 0.05, losses
    assert losses[2] > losses[16] + 0.02, losses


def test_qgrad_loss_matches_qloss(setup):
    params, toks = setup
    graphs = make_graphs(CFG)
    args = [toks] + uniform_bits(3) + params_to_list(CFG, params)
    l1 = float(graphs["qloss"](*args)[0])
    out = graphs["qgrad"](*args)
    assert len(out) == 1 + len(CFG.quantized_names())
    np.testing.assert_allclose(float(out[0]), l1, rtol=1e-6)


def test_qgrad_is_gradient_at_quantized_point(setup):
    """Finite-difference check of one gradient entry at w^Q (paper Eq. 3)."""
    params, toks = setup
    graphs = make_graphs(CFG)
    from compile.model import fakequant_params
    bits = uniform_bits(3)
    plist = params_to_list(CFG, params)
    out = graphs["qgrad"](*([toks] + bits + plist))
    g_wq = np.asarray(out[1])  # grad of layers.0.wq

    qp = fakequant_params(CFG, params, bits)
    name = CFG.quantized_names()[0]
    eps = 1e-3
    ij = (1, 2)
    for sign in (+1,):
        pp = dict(qp)
        pp[name] = qp[name].at[ij].add(eps)
        lp = float(ce_loss(forward(CFG, pp, toks), toks))
        pm = dict(qp)
        pm[name] = qp[name].at[ij].add(-eps)
        lm = float(ce_loss(forward(CFG, pm, toks), toks))
        fd = (lp - lm) / (2 * eps)
    assert abs(fd - g_wq[ij]) < 5e-3 * max(1.0, abs(fd)), (fd, g_wq[ij])


def test_qlogits_matches_forward_of_fakequant(setup):
    params, toks = setup
    graphs = make_graphs(CFG)
    from compile.model import fakequant_params
    bits = uniform_bits(4)
    args = [toks] + bits + params_to_list(CFG, params)
    ql = graphs["qlogits"](*args)[0]
    qp = fakequant_params(CFG, params, bits)
    want = forward(CFG, qp, toks)
    np.testing.assert_allclose(np.asarray(ql), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_grams_shapes_and_psd(setup):
    params, toks = setup
    graphs = make_graphs(CFG)
    args = [toks] + fp_bits() + params_to_list(CFG, params)
    out = graphs["grams"](*args)
    # first output is the loss (keeps all params live under XLA DCE)
    assert len(out) == 1 + 4 * CFG.n_layers
    assert np.isfinite(float(out[0]))
    grams = out[1:]
    dims = []
    for i in range(CFG.n_layers):
        dims += [CFG.d_model, CFG.d_model, CFG.d_model, CFG.d_ff]
    for g, d in zip(grams, dims):
        g = np.asarray(g)
        assert g.shape == (d, d)
        np.testing.assert_allclose(g, g.T, rtol=1e-4, atol=1e-4)
        evals = np.linalg.eigvalsh(g)
        assert evals.min() > -1e-2 * max(1.0, evals.max())


def test_graph_arg_specs_align():
    specs = graph_arg_specs(CFG, 4)
    assert specs[0].shape == (4, CFG.seq_len)
    nq = len(CFG.quantized_names())
    for i, n in enumerate(CFG.quantized_names()):
        assert specs[1 + i].shape == CFG.bits_shape(n)
    for i, n in enumerate(CFG.param_names()):
        assert specs[1 + nq + i].shape == CFG.param_shape(n)


def test_rope_preserves_norm():
    from compile.model import rope
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, 4, 32)), jnp.float32)
    r = rope(x, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1), rtol=1e-5)


def test_rmsnorm_unit_scale():
    from compile.model import rmsnorm
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    y = np.asarray(rmsnorm(x, jnp.ones(16)))
    rms = np.sqrt(np.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
