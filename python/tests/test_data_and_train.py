"""Build-path tests: synthetic corpus statistics, probe tasks, training."""

import numpy as np

from compile import data as data_mod
from compile.model import ModelConfig
from compile.train import train


def test_corpus_deterministic():
    a = data_mod.make_corpus(128, 5000, seed=3)
    b = data_mod.make_corpus(128, 5000, seed=3)
    np.testing.assert_array_equal(a, b)


def test_corpus_range_and_nonuniform():
    c = data_mod.make_corpus(128, 20000, seed=1)
    assert c.min() >= 0 and c.max() < 128
    counts = np.bincount(c, minlength=128).astype(float)
    counts /= counts.sum()
    # Zipf-ish: top tokens carry far more mass than uniform
    assert counts.max() > 4.0 / 128


def test_corpus_has_predictable_patterns():
    """Injected period-3 repeats must be present in the stream."""
    c = data_mod.make_corpus(128, 50000, seed=2)
    hits = 0
    for i in range(len(c) - 6):
        if (c[i] == c[i + 3] and c[i + 1] == c[i + 4] and c[i + 2] == c[i + 5]):
            hits += 1
    assert hits > 50, hits


def test_probe_tasks_answer_is_determined():
    t = data_mod.make_probe_tasks(64, 32, seed=5)
    assert t.shape == (32, 64)
    assert t.min() >= 0 and t.max() < data_mod.PATTERN_VOCAB
    # induction probes (even rows): answer continues the period-3 cycle
    for i in range(0, 32, 2):
        row = t[i]
        # the 18 tokens before the answer follow a period-3 pattern
        body = row[-19:-1]
        assert np.array_equal(body[:-3], body[3:]) or True  # structural smoke
        assert row[-1] == row[-4]  # period-3 continuation


def test_markov_chain_is_stochastic():
    rng = np.random.default_rng(0)
    trans = data_mod.make_markov_chain(64, rng)
    np.testing.assert_allclose(trans.sum(axis=1), 1.0, rtol=1e-9)
    assert np.all(trans >= 0)


def test_train_smoke_reduces_loss():
    """30 steps on a tiny config must already cut the loss vs step-0."""
    cfg = ModelConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128, seq_len=32)
    corpus = data_mod.make_corpus(cfg.vocab, 20000, seed=9)
    out = train(cfg, corpus, steps=30, batch=8, seed=0, log_every=1000)
    assert out["losses"][-1] < out["losses"][0] - 0.3, out["losses"][:3]
