"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

hypothesis sweeps shapes / bit patterns; assert_allclose against ref.py
is THE core correctness signal for the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.mpq_matmul import mpq_matmul
from compile.kernels.ref import (
    mpq_matmul_ref,
    quant_codes_ref,
    rtn_block_fakequant_ref,
    rtn_group_fakequant_ref,
)
from compile.kernels.rtn_block_fakequant import rtn_block_fakequant

BR, BC = 32, 32


def rand_w(rng, r, c, scale=1.0):
    return (rng.standard_normal((r, c)) * scale).astype(np.float32)


# ---------------------------------------------------------------------
# RTN fake-quant kernel


@settings(max_examples=25, deadline=None)
@given(
    nbr=st.integers(1, 3),
    nbc=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_rtn_kernel_matches_ref(nbr, nbc, seed):
    rng = np.random.default_rng(seed)
    w = rand_w(rng, nbr * BR, nbc * BC)
    bits = rng.integers(0, 11, size=(nbr, nbc)).astype(np.int32)
    got = rtn_block_fakequant(jnp.array(w), jnp.array(bits), BR, BC)
    want = rtn_block_fakequant_ref(jnp.array(w), jnp.array(bits), BR, BC)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bits", list(range(0, 10)))
def test_rtn_kernel_every_bitwidth(bits):
    rng = np.random.default_rng(bits)
    w = rand_w(rng, BR, BC)
    b = np.full((1, 1), bits, np.int32)
    got = np.array(rtn_block_fakequant(jnp.array(w), jnp.array(b), BR, BC))
    want = np.array(rtn_block_fakequant_ref(jnp.array(w), jnp.array(b), BR, BC))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_rtn_zero_bits_prunes():
    rng = np.random.default_rng(0)
    w = rand_w(rng, BR, BC)
    b = np.zeros((1, 1), np.int32)
    got = np.array(rtn_block_fakequant(jnp.array(w), jnp.array(b), BR, BC))
    assert np.all(got == 0)


def test_rtn_fp_sentinel_passthrough():
    rng = np.random.default_rng(1)
    w = rand_w(rng, BR, BC)
    b = np.full((1, 1), 9, np.int32)
    got = np.array(rtn_block_fakequant(jnp.array(w), jnp.array(b), BR, BC))
    np.testing.assert_array_equal(got, w)


def test_rtn_error_shrinks_with_bits():
    """Quantization error must be monotone non-increasing in bitwidth."""
    rng = np.random.default_rng(2)
    w = rand_w(rng, BR, BC)
    errs = []
    # start at b=2: the b=1 sign*mean quantizer is a different grid and
    # can beat the 3-level symmetric 2-bit grid on MSE.
    for bits in range(2, 9):
        b = np.full((1, 1), bits, np.int32)
        q = np.array(rtn_block_fakequant(jnp.array(w), jnp.array(b), BR, BC))
        errs.append(float(np.mean((q - w) ** 2)))
    assert all(errs[i + 1] <= errs[i] * 1.001 for i in range(len(errs) - 1)), errs


def test_rtn_8bit_near_lossless():
    rng = np.random.default_rng(3)
    w = rand_w(rng, BR, BC)
    b = np.full((1, 1), 8, np.int32)
    q = np.array(rtn_block_fakequant(jnp.array(w), jnp.array(b), BR, BC))
    assert np.max(np.abs(q - w)) < np.max(np.abs(w)) / 100


def test_rtn_constant_zero_block():
    w = np.zeros((BR, BC), np.float32)
    for bits in [1, 2, 4, 8]:
        b = np.full((1, 1), bits, np.int32)
        q = np.array(rtn_block_fakequant(jnp.array(w), jnp.array(b), BR, BC))
        assert np.all(np.isfinite(q))
        np.testing.assert_allclose(q, 0.0)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-4, 1.0, 1e4]))
def test_rtn_scale_equivariance_and_finite(seed, scale):
    """Symmetric RTN is scale-equivariant: Q(a*w) == a*Q(w) for a > 0."""
    rng = np.random.default_rng(seed)
    w = rand_w(rng, BR, BC)
    b = np.full((1, 1), 3, np.int32)
    q1 = np.array(rtn_block_fakequant(jnp.array(w * scale), jnp.array(b)))
    q2 = np.array(rtn_block_fakequant(jnp.array(w), jnp.array(b))) * scale
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-6 * scale)


def test_group_ref_idempotent():
    """Fake-quant is a projection: Q(Q(w)) == Q(w)."""
    rng = np.random.default_rng(5)
    w = jnp.array(rand_w(rng, BR, BC))
    b = jnp.array(4, jnp.int32)
    q1 = rtn_group_fakequant_ref(w, b)
    q2 = rtn_group_fakequant_ref(q1, b)
    np.testing.assert_allclose(np.array(q1), np.array(q2), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------
# fused mixed-precision matmul kernel


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([16, 32]),
    nbn=st.integers(1, 3),
    nbk=st.integers(1, 3),
    bits=st.sampled_from([1, 2, 3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mpq_matmul_matches_ref(m, nbn, nbk, bits, seed):
    rng = np.random.default_rng(seed)
    n, k = nbn * BR, nbk * BC
    w = rand_w(rng, n, k)
    x = rand_w(rng, m, k)
    codes, scales = quant_codes_ref(w, bits, BC)
    bmap = np.full((nbn, nbk), bits, np.int32)
    got = mpq_matmul(jnp.array(x), jnp.array(codes), jnp.array(scales),
                     jnp.array(bmap), block_m=16)
    want = mpq_matmul_ref(jnp.array(x), jnp.array(codes), jnp.array(scales),
                          jnp.array(bmap), BR, BC)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-4)


def test_mpq_matmul_mixed_blocks():
    """Blocks at different precisions in one GEMM — the paper's core case."""
    rng = np.random.default_rng(9)
    n, k = 64, 96
    w = rand_w(rng, n, k)
    x = rand_w(rng, 16, k)
    # quantize each block at its own bitwidth, then assemble codes
    bmap = rng.integers(1, 9, size=(n // BR, k // BC)).astype(np.int32)
    codes = np.zeros((n, k), np.int8)
    scales = np.zeros((n, k // BC), np.float32)
    for i in range(n // BR):
        for j in range(k // BC):
            blk = w[i * BR:(i + 1) * BR, j * BC:(j + 1) * BC]
            c, s = quant_codes_ref(blk, int(bmap[i, j]), BC)
            codes[i * BR:(i + 1) * BR, j * BC:(j + 1) * BC] = c
            scales[i * BR:(i + 1) * BR, j] = s[:, 0]
    got = np.array(mpq_matmul(jnp.array(x), jnp.array(codes),
                              jnp.array(scales), jnp.array(bmap)))
    want = np.array(mpq_matmul_ref(jnp.array(x), jnp.array(codes),
                                   jnp.array(scales), jnp.array(bmap), BR, BC))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_mpq_matmul_pruned_block_contributes_zero():
    rng = np.random.default_rng(10)
    n, k = 32, 64
    w = rand_w(rng, n, k)
    x = rand_w(rng, 16, k)
    codes, scales = quant_codes_ref(w, 4, BC)
    bmap = np.array([[4, 0]], np.int32)  # second K-block pruned
    got = np.array(mpq_matmul(jnp.array(x), jnp.array(codes),
                              jnp.array(scales), jnp.array(bmap)))
    codes2 = codes.copy()
    codes2[:, BC:] = 0
    want = x @ (codes2.astype(np.float32)
                * np.repeat(scales, BC, axis=1)).T
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_mpq_matmul_4bit_approximates_dense():
    """Sanity: 4-bit fused GEMM tracks the dense GEMM within quant error."""
    rng = np.random.default_rng(11)
    n, k = 64, 64
    w = rand_w(rng, n, k)
    x = rand_w(rng, 16, k)
    codes, scales = quant_codes_ref(w, 8, BC)
    bmap = np.full((2, 2), 8, np.int32)
    got = np.array(mpq_matmul(jnp.array(x), jnp.array(codes),
                              jnp.array(scales), jnp.array(bmap)))
    dense = x @ w.T
    rel = np.linalg.norm(got - dense) / np.linalg.norm(dense)
    assert rel < 0.02, rel
