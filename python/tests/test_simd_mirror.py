"""Bit-level mirror of rust/src/kernel/simd.rs (numpy only, no JAX).

Three claims from the kernel SIMD module are checked host-side, so they
hold even when the host toolchain cannot run the AVX2/NEON paths:

1. The AVX2 and NEON decode *instruction sequences* (nibble splits,
   unpack/zip interleaves, pshufb/vqtbl1 sign-extension LUTs, bit-test
   selects) produce exactly the scalar two's-complement decode, code
   for code, for every vectorized bitwidth {1, 2, 4, 8} — simulated
   here at integer level, including the shared ragged-tail epilogue.
2. The pinned-lane dot algebra: scalar ([f32; 32] array), AVX2 (4 ymm
   registers) and NEON (8 q registers) all assign element j to lane
   j % 32 and visit blocks in the same order, so given IEEE fused
   multiply-adds they are bitwise identical by construction. We verify
   the *schedules* (per-lane element index sequences + reduction tree)
   are equal, which is the entire difference between the paths.
3. The f32 serving-activation tolerance contract: the interp_golden
   forward run in float32 (RoPE tables computed in f64 then cast, the
   same shape as the rust ModelF32) keeps every argmax token identical
   to the float64 forward, with logits inside 1e-3 + 1e-3*|f64| and an
   argmax margin comfortably above the observed divergence.

Run: python -m pytest python/tests/test_simd_mirror.py -q
"""

from __future__ import annotations

import numpy as np

from compile.interp_golden import (
    GOLDEN_TOKENS_XOR,
    QUANT_LEAVES,
    RMS_EPS,
    ROPE_THETA,
    SPEC,
    Rng,
    fakequant,
    forward,
    silu,
    softmax,
    token_stream,
    weight_store,
)

MASK64 = (1 << 64) - 1
LANES = 32  # kernel::simd::LANES


# ---------------------------------------------------------------------
# scalar decode mirror (simd::decode_scalar_range)


def sign_extend(v: int, bits: int) -> int:
    sign = 1 << (bits - 1)
    return v - (1 << bits) if v & sign else v


def decode_scalar(seg: list[int], bits: int, scale: np.float32, n: int):
    out = np.zeros(n, np.float32)
    if bits == 1:
        for t in range(n):
            bit = (seg[t >> 6] >> (t & 63)) & 1
            out[t] = scale if bit == 1 else -scale
        return out
    if bits in (2, 4, 8):
        cpw = 64 // bits
        for t in range(n):
            code = (seg[t // cpw] >> ((t % cpw) * bits)) & ((1 << bits) - 1)
            out[t] = np.float32(sign_extend(code, bits)) * scale
        return out
    # generic straddling path (3/5/6/7)
    mask = (1 << bits) - 1
    for t in range(n):
        bitpos = t * bits
        wi, off = bitpos >> 6, bitpos & 63
        v = seg[wi] >> off
        if off + bits > 64:
            v |= seg[wi + 1] << (64 - off)
        out[t] = np.float32(sign_extend(v & mask, bits)) * scale
    return out


# ---------------------------------------------------------------------
# AVX2 decode sequence simulation (x86::decode{1,2,4,8})


def word_bytes(w: int) -> list[int]:
    return [(w >> (8 * j)) & 0xFF for j in range(8)]


def unpacklo_epi8(a: list[int], b: list[int]) -> list[int]:
    out = []
    for j in range(8):
        out += [a[j], b[j]]
    return out


def unpackhi_epi8(a: list[int], b: list[int]) -> list[int]:
    out = []
    for j in range(8, 16):
        out += [a[j], b[j]]
    return out


LUT4 = [0, 1, 2, 3, 4, 5, 6, 7, -8, -7, -6, -5, -4, -3, -2, -1]
LUT2 = [0, 1, -2, -1]


def avx2_decode(seg: list[int], bits: int, scale: np.float32, n: int):
    out = np.zeros(n, np.float32)
    if bits == 8:
        full = n // 8
        for wi in range(full):
            for j, byte in enumerate(word_bytes(seg[wi])):
                code = byte - 256 if byte >= 128 else byte  # cvtepi8_epi32
                out[wi * 8 + j] = np.float32(code) * scale
        tail = full * 8
    elif bits == 4:
        full = n // 16
        for wi in range(full):
            by = word_bytes(seg[wi])
            lo = [b & 0x0F for b in by]
            hi = [(b >> 4) & 0x0F for b in by]
            nib = unpacklo_epi8(lo, hi)  # codes 0..15 in order
            for j, v in enumerate(nib):
                out[wi * 16 + j] = np.float32(LUT4[v]) * scale  # pshufb
        tail = full * 16
    elif bits == 2:
        full = n // 32
        for wi in range(full):
            by = word_bytes(seg[wi])
            lo = [b & 0x0F for b in by]
            hi = [(b >> 4) & 0x0F for b in by]
            nib = unpacklo_epi8(lo, hi)  # 16 nibbles, nibble order
            clo = [v & 0x03 for v in nib]
            chi = [(v >> 2) & 0x03 for v in nib]
            codes = unpacklo_epi8(clo, chi) + unpackhi_epi8(clo, chi)
            for j, v in enumerate(codes):
                out[wi * 32 + j] = np.float32(LUT2[v]) * scale
        tail = full * 32
    elif bits == 1:
        full = n // 64
        sel = [1, 2, 4, 8, 16, 32, 64, 128]
        for wi in range(full):
            for by_i, byte in enumerate(word_bytes(seg[wi])):
                for lane in range(8):  # and + cmpeq + blendv
                    hit = byte & sel[lane]
                    out[wi * 64 + by_i * 8 + lane] = scale if hit == sel[lane] else -scale
        tail = full * 64
    else:
        raise AssertionError(bits)
    if tail < n:
        out[tail:] = decode_scalar(seg, bits, scale, n)[tail:]
    return out


# ---------------------------------------------------------------------
# NEON decode sequence simulation (neon::decode{1,2,4,8})


def vzip1_u8(a: list[int], b: list[int]) -> list[int]:
    out = []
    for j in range(4):
        out += [a[j], b[j]]
    return out


def vzip2_u8(a: list[int], b: list[int]) -> list[int]:
    out = []
    for j in range(4, 8):
        out += [a[j], b[j]]
    return out


def vzip1q_u8(a: list[int], b: list[int]) -> list[int]:
    out = []
    for j in range(8):
        out += [a[j], b[j]]
    return out


def vzip2q_u8(a: list[int], b: list[int]) -> list[int]:
    out = []
    for j in range(8, 16):
        out += [a[j], b[j]]
    return out


def neon_decode(seg: list[int], bits: int, scale: np.float32, n: int):
    out = np.zeros(n, np.float32)
    if bits == 8:
        full = n // 8
        for wi in range(full):
            for j, byte in enumerate(word_bytes(seg[wi])):  # vmovl_s8 widen
                code = byte - 256 if byte >= 128 else byte
                out[wi * 8 + j] = np.float32(code) * scale  # vmulq_n_f32
        tail = full * 8
    elif bits == 4:
        full = n // 16
        for wi in range(full):
            by = word_bytes(seg[wi])
            lo = [b & 0x0F for b in by]
            hi = [(b >> 4) & 0x0F for b in by]  # vshr_n_u8::<4>
            nib = vzip1_u8(lo, hi) + vzip2_u8(lo, hi)  # vcombine(zip1, zip2)
            for j, v in enumerate(nib):
                out[wi * 16 + j] = np.float32(LUT4[v]) * scale  # vqtbl1q_s8
        tail = full * 16
    elif bits == 2:
        full = n // 32
        for wi in range(full):
            by = word_bytes(seg[wi])
            lo = [b & 0x0F for b in by]
            hi = [(b >> 4) & 0x0F for b in by]
            nib = vzip1_u8(lo, hi) + vzip2_u8(lo, hi)
            clo = [v & 0x03 for v in nib]
            chi = [(v >> 2) & 0x03 for v in nib]
            codes = vzip1q_u8(clo, chi) + vzip2q_u8(clo, chi)
            for j, v in enumerate(codes):
                out[wi * 32 + j] = np.float32(LUT2[v]) * scale
        tail = full * 32
    elif bits == 1:
        full = n // 64
        sel = [1, 2, 4, 8, 16, 32, 64, 128]  # sel_lo ++ sel_hi
        for wi in range(full):
            for by_i, byte in enumerate(word_bytes(seg[wi])):
                for lane in range(8):  # vtstq_u32 + vbslq_f32
                    out[wi * 64 + by_i * 8 + lane] = (
                        scale if byte & sel[lane] else -scale
                    )
        tail = full * 64
    else:
        raise AssertionError(bits)
    if tail < n:
        out[tail:] = decode_scalar(seg, bits, scale, n)[tail:]
    return out


def rand_words(rng: Rng, n: int) -> list[int]:
    return [rng.next_u64() for _ in range(n)]


def test_avx2_and_neon_decode_sequences_match_scalar_bitwise():
    rng = Rng(0x51D0)
    lens = [1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 200]
    for bits in (1, 2, 4, 8):
        for n in lens:
            words = -(-(n * bits) // 64)
            seg = rand_words(rng, words)
            scale = np.float32(abs(np.float32(rng.f64() * 2 - 1)) + 1e-3)
            want = decode_scalar(seg, bits, scale, n)
            for name, got in (
                ("avx2", avx2_decode(seg, bits, scale, n)),
                ("neon", neon_decode(seg, bits, scale, n)),
            ):
                same = got.view(np.uint32) == want.view(np.uint32)
                assert same.all(), (
                    f"{name} bits={bits} n={n} first mismatch at "
                    f"{int(np.argmin(same))}"
                )


def test_straddling_widths_have_no_vector_decoder():
    # 3/5/6/7-bit fields cross u64 boundaries; the rust dispatch sends
    # them to the scalar loop on every ISA. Sanity-check the straddle
    # reconstruction against a direct big-integer bit extraction.
    rng = Rng(0xBEEF)
    for bits in (3, 5, 6, 7):
        n = 173
        words = -(-(n * bits) // 64)
        seg = rand_words(rng, words)
        big = 0
        for i, w in enumerate(seg):
            big |= w << (64 * i)
        scale = np.float32(0.125)
        got = decode_scalar(seg, bits, scale, n)
        for t in range(n):
            code = sign_extend((big >> (t * bits)) & ((1 << bits) - 1), bits)
            assert got[t] == np.float32(code) * scale


# ---------------------------------------------------------------------
# pinned-lane dot schedule equality


def dot_schedule(n: int, regs: int):
    """Per-lane element visit order for a path using `regs` registers of
    width LANES/regs (scalar: 32 registers of 1; AVX2: 4 of 8; NEON: 8
    of 4). Returns (lanes, tail, tree) where lanes[l] lists the element
    indices lane l fuses in order, tail is the shared ragged epilogue,
    and tree is the fixed reduction order."""
    width = LANES // regs
    lanes = [[] for _ in range(LANES)]
    nb = n // LANES
    for t in range(nb):
        base = t * LANES
        for r in range(regs):
            for w in range(width):
                lane = r * width + w
                lanes[lane].append(base + lane)
    tail = [(j % LANES, j) for j in range(nb * LANES, n)]
    tree, half = [], LANES // 2
    while True:
        tree += [(l, l + half) for l in range(half)]
        if half == 1:
            return lanes, tail, tree
        half //= 2


def test_dot_lane_schedules_identical_across_paths():
    # Same per-lane element sequences + same tail + same reduction tree
    # == same f32 expression graph == bitwise-equal results under IEEE
    # fused multiply-add. This is the entire scalar/AVX2/NEON delta.
    for n in (0, 1, 5, 31, 32, 33, 64, 95, 127, 128, 257, 1024, 1031):
        scalar = dot_schedule(n, regs=LANES)
        avx2 = dot_schedule(n, regs=4)
        neon = dot_schedule(n, regs=8)
        assert scalar == avx2 == neon
        # every element is fused exactly once, into lane j % LANES
        lanes, tail, _ = scalar
        seen = sorted(sum(lanes, []) + [j for (_, j) in tail])
        assert seen == list(range(n))
        for l, seq in enumerate(lanes):
            assert all(j % LANES == l for j in seq)


# ---------------------------------------------------------------------
# f32 serving forward vs f64 golden forward (tolerance contract)


def rope32(x):
    b, t, h, hd = x.shape
    half = hd // 2
    freqs = ROPE_THETA ** (-np.arange(half, dtype=np.float64) / half)
    ang = np.arange(t, dtype=np.float64)[:, None] * freqs[None, :]
    # tables computed in f64 then cast once — same as rust ModelF32
    cos = np.cos(ang).astype(np.float32)
    sin = np.sin(ang).astype(np.float32)
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos[None, :, None, :] - x2 * sin[None, :, None, :]
    rx2 = x1 * sin[None, :, None, :] + x2 * cos[None, :, None, :]
    return np.concatenate([rx1, rx2], axis=-1)


def forward32(spec, params, tokens):
    b, t = tokens.shape
    d, h = spec["d_model"], spec["n_heads"]
    hd = d // h

    def norm(x, g):
        var = np.mean(x * x, axis=-1, keepdims=True)
        return x / np.sqrt(var + np.float32(RMS_EPS)) * g

    x = params["embed"][tokens]
    assert x.dtype == np.float32
    for i in range(spec["n_layers"]):
        p = f"layers.{i}."
        hh = norm(x, params[p + "attn_norm"])
        q = (hh @ params[p + "wq"].T).reshape(b, t, h, hd)
        k = (hh @ params[p + "wk"].T).reshape(b, t, h, hd)
        v = (hh @ params[p + "wv"].T).reshape(b, t, h, hd)
        q, k = rope32(q), rope32(k)
        att = np.einsum("bthd,bshd->bhts", q, k) / np.float32(np.sqrt(hd))
        mask = np.tril(np.ones((t, t), bool))
        att = np.where(mask[None, None], att, np.float32(-1e30))
        att = softmax(att.astype(np.float32), axis=-1)
        out = np.einsum("bhts,bshd->bthd", att, v).reshape(b, t, d)
        x = x + out @ params[p + "wo"].T
        hh = norm(x, params[p + "mlp_norm"])
        hp = silu(hh @ params[p + "w_gate"].T) * (hh @ params[p + "w_up"].T)
        x = x + hp @ params[p + "w_down"].T
        assert x.dtype == np.float32
    x = norm(x, params["final_norm"])
    return x @ params["lm_head"].T


def test_f32_forward_keeps_tokens_and_bounds_logit_divergence():
    spec = SPEC
    store = weight_store(spec)
    tokens = token_stream(
        spec["batch"] * spec["seq_len"], spec["vocab"],
        spec["seed"] ^ GOLDEN_TOKENS_XOR,
    ).reshape(spec["batch"], spec["seq_len"])

    # mixed per-matrix allocation, same spirit as the rust decode-sweep
    # test: cycle every vectorized family plus FP passthrough
    cycle = [2, 4, 8, 16]
    p64, p32, qi = {}, {}, 0
    for name, w in store.items():
        leaf = name.rsplit(".", 1)[-1]
        if leaf in QUANT_LEAVES:
            wq = fakequant(w, cycle[qi % len(cycle)], spec["block_cols"])
            qi += 1
        else:
            wq = w
        p64[name] = wq.astype(np.float64)
        p32[name] = wq.astype(np.float32)

    l64 = forward(spec, p64, tokens)
    l32 = forward32(spec, p32, tokens).astype(np.float64)
    assert l64.shape == l32.shape

    # token IDs must not move, at every position of every row
    a64 = l64.argmax(axis=-1)
    a32 = l32.argmax(axis=-1)
    assert (a64 == a32).all(), f"{int((a64 != a32).sum())} argmax flips"

    # per-element tolerance gate, identical to the rust tests
    tol = 1e-3 + 1e-3 * np.abs(l64)
    worst = np.max(np.abs(l32 - l64) / tol)
    assert worst <= 1.0, f"divergence {worst:.3f}x of the tolerance gate"

    # margin analysis: the top-1/top-2 gap must dominate the observed
    # absolute divergence, otherwise token stability would be luck
    s = np.sort(l64, axis=-1)
    margin = np.min(s[..., -1] - s[..., -2])
    max_abs_err = np.max(np.abs(l32 - l64))
    assert margin > 4.0 * max_abs_err, (
        f"min argmax margin {margin:.2e} vs f32 divergence {max_abs_err:.2e}"
    )
