"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes/dtypes/bits
with hypothesis and asserts the Pallas kernels (interpret mode) match
these implementations to float tolerance. The rust RTN quantizer is also
cross-validated against `rtn_block_fakequant_ref` through golden vectors
exported by aot.py.

Quantization scheme (paper §5: RTN, group size = block width):
  - per-block bitwidth b (uniform inside a hardware tile),
  - per-(row, col-group) scale, symmetric grid,
  - b == 1  -> sign(w) * mean|w| over the group (binary special case),
  - b >= 9  -> passthrough (sentinel for "keep full precision").
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FP_SENTINEL_BITS = 9  # bits >= this means "leave the block in full precision"


def rtn_group_fakequant_ref(w: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize one (rows, group) tile with a single bitwidth.

    w:    [rows, g] float32
    bits: scalar int32
    """
    bf = bits.astype(jnp.float32)
    qmax = jnp.exp2(bf - 1.0) - 1.0  # 2^(b-1) - 1 symmetric levels
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale = amax / jnp.maximum(qmax, 1.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(w / safe), -qmax, qmax)
    deq = q * scale

    # 1-bit: sign * mean|w| per row-group.
    mean_abs = jnp.mean(jnp.abs(w), axis=-1, keepdims=True)
    sgn = jnp.where(w >= 0, 1.0, -1.0)
    one_bit = sgn * mean_abs

    out = jnp.where(bits == 1, one_bit, deq)
    out = jnp.where(bits >= FP_SENTINEL_BITS, w, out)
    # 0-bit: pruned block.
    out = jnp.where(bits <= 0, jnp.zeros_like(w), out)
    return out


def rtn_block_fakequant_ref(
    w: jnp.ndarray, bits: jnp.ndarray, block_rows: int, block_cols: int
) -> jnp.ndarray:
    """Fake-quantize a full matrix with per-block bitwidths.

    w:    [R, C] float32
    bits: [R // block_rows, C // block_cols] int32
    Scales are per (row, block-col) => group size == block_cols,
    matching the paper's "quantization group size must match the block
    width" constraint (App. E.6).
    """
    import jax

    R, C = w.shape
    br, bc = block_rows, block_cols
    # [nbr, nbc, br, bc]: one leading entry per block.
    gw = w.reshape(R // br, br, C // bc, bc).transpose(0, 2, 1, 3)

    out = jax.vmap(jax.vmap(rtn_group_fakequant_ref))(gw, bits)
    return out.transpose(0, 2, 1, 3).reshape(R, C)


def quant_codes_ref(w: np.ndarray, bits: int, group: int):
    """Integer codes + scales for real (packed) quantization (numpy).

    Used as golden data for the rust packer. Returns (codes int8 [R, C],
    scales f32 [R, C//group]). bits in 1..8.
    """
    R, C = w.shape
    wg = w.reshape(R, C // group, group)
    if bits == 1:
        scales = np.mean(np.abs(wg), axis=-1)
        codes = np.where(wg >= 0, 1, -1).astype(np.int8)
        return codes.reshape(R, C), scales.astype(np.float32)
    qmax = float(2 ** (bits - 1) - 1)
    amax = np.max(np.abs(wg), axis=-1)
    scales = amax / max(qmax, 1.0)
    safe = np.where(scales > 0, scales, 1.0)[..., None]
    codes = np.clip(np.round(wg / safe), -qmax, qmax).astype(np.int8)
    return codes.reshape(R, C), scales.astype(np.float32)


def mpq_matmul_ref(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    bits: jnp.ndarray,
    block_rows: int,
    block_cols: int,
) -> jnp.ndarray:
    """Reference for the fused dequant+matmul kernel.

    x:      [M, K]  float32 activations
    codes:  [N, K]  int8    quantized weight codes (row-major, W[N, K])
    scales: [N, K // block_cols] float32 per-(row, col-group) scales
    bits:   [N // block_rows, K // block_cols] int32 (only the pruned-
            block zero mask is needed here; code values already encode
            the precision)
    returns y = x @ W_deq^T : [M, N]
    """
    deq = codes.astype(jnp.float32) * jnp.repeat(scales, block_cols, axis=1)
    mask = jnp.repeat(
        jnp.repeat((bits > 0).astype(jnp.float32), block_rows, axis=0),
        block_cols,
        axis=1,
    )
    return x @ (deq * mask).T
