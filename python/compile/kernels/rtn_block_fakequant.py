"""L1 Pallas kernel: block-wise RTN fake-quantization.

This is the hot inner op of the *search* path: every iteration of the
scalable greedy search (Algorithm 1) re-quantizes the model under a new
per-block bit allocation. Placing `Q(w, b)` on-device means the rust
coordinator only re-uploads the tiny int32 `bits` grids each iteration;
the full-precision weights live in device buffers uploaded once.

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid step = one
hardware tile staged HBM->VMEM. The per-tile bitwidth is a (1,1) scalar
block rider; the dequant grid math is pure VPU element-wise work that
fuses ahead of whatever consumes the tile (here: the transformer's
matmuls). All precision branches are computed branchlessly with
`jnp.where`, which is exactly why per-tile mixed precision costs nothing
at runtime — there is no control-flow divergence across tiles.

interpret=True: CPU PJRT cannot execute Mosaic custom-calls; interpret
mode lowers the kernel to plain HLO so the same artifact runs everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FP_SENTINEL_BITS = 9


def _fakequant_tile(w, bits):
    """Branchless RTN fake-quant, vectorized over [..., group] tiles.

    `w` is [..., g]; `bits` broadcasts against w's leading axes (the
    group axis reduces). Works for a single tile ([br, bc] with scalar
    bits) and for a whole stripe ([br, nbc, bc] with bits [1, nbc, 1]).
    """
    bf = bits.astype(jnp.float32)
    qmax = jnp.exp2(bf - 1.0) - 1.0
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale = amax / jnp.maximum(qmax, 1.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(w / safe), -qmax, qmax)
    deq = q * scale

    mean_abs = jnp.mean(jnp.abs(w), axis=-1, keepdims=True)
    one_bit = jnp.where(w >= 0, 1.0, -1.0) * mean_abs

    out = jnp.where(bits == 1, one_bit, deq)
    out = jnp.where(bits >= FP_SENTINEL_BITS, w, out)
    out = jnp.where(bits <= 0, jnp.zeros_like(w), out)
    return out


def _stripe_kernel(w_ref, bits_ref, o_ref):
    # One grid step = one block-row STRIPE: [br, C] staged into VMEM,
    # reshaped to [br, nbc, bc] so every column tile quantizes in one
    # vectorized VPU pass against its own (1, nbc, 1) bit scalar.
    w = w_ref[...]
    br, c = w.shape
    nbc = bits_ref.shape[1]
    w3 = w.reshape(br, nbc, c // nbc)
    bits3 = bits_ref[...].reshape(1, nbc, 1)
    o_ref[...] = _fakequant_tile(w3, bits3).reshape(br, c)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def rtn_block_fakequant(
    w: jnp.ndarray, bits: jnp.ndarray, block_rows: int = 32, block_cols: int = 32
) -> jnp.ndarray:
    """Fake-quantize matrix `w` [R, C] under per-block bits [R/br, C/bc].

    Per-(row, col-group) symmetric scales with group size == block_cols.

    Schedule (perf pass, EXPERIMENTS.md §Perf): the grid iterates over
    block-row stripes only — each step stages a [br, C] stripe
    HBM->VMEM and quantizes all of its column tiles in one vectorized
    pass (C = 128-256 here => 16-32 KB per stripe, comfortably inside
    VMEM; at LLM scale the stripe would be sub-tiled along C). The
    original (R/br, C/bc) per-tile grid lowered (interpret mode) to
    ~10x more sequential loop steps and dominated the qloss/qgrad
    executables' runtime.
    """
    R, C = w.shape
    br, bc = block_rows, block_cols
    assert R % br == 0 and C % bc == 0, (w.shape, br, bc)
    grid = (R // br,)
    return pl.pallas_call(
        _stripe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, C), lambda i: (i, 0)),
            pl.BlockSpec((1, C // bc), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=True,
    )(w, bits)
