"""L1 Pallas kernel: fused block-wise mixed-precision dequant + matmul.

The serving-path hot spot (paper §5.3 "Inference Kernel"). The paper
fuses dequantization with GEMM in Triton so each Tensor-Core tile sees a
uniform bitwidth and mixed precision introduces no warp divergence. The
TPU translation (DESIGN.md §Hardware-Adaptation):

  * grid = (M/bm, N/bn, K/bk) — one step stages an activation tile
    [bm, bk] and a code tile [bn, bk] from HBM into VMEM,
  * the per-tile (scale, bits) ride along as small blocks,
  * dequant (codes * scale) is VPU element-wise work fused immediately
    ahead of the MXU tile matmul,
  * partial products accumulate into the output VMEM tile across the K
    grid dimension (initialized at k == 0), i.e. the classic
    double-buffered K-loop reduction schedule.

Because the code values already encode the per-block precision, the tile
program is IDENTICAL for every bitwidth — this is the "no measurable
latency overhead" property of Table 4, reproduced structurally.

Weight layout: y = x @ W^T with W stored row-major [N, K], codes int8,
scales per (row, col-group), group == bk (block width).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, codes_ref, scales_ref, bits_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [bm, bk] f32
    codes = codes_ref[...].astype(jnp.float32)  # [bn, bk]
    scale = scales_ref[...]  # [bn, 1]
    # A pruned tile (bits == 0) contributes nothing.
    live = (bits_ref[0, 0] > 0).astype(jnp.float32)
    deq = codes * scale * live  # fused on-the-fly dequant
    o_ref[...] += jnp.dot(x, deq.T, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_rows", "block_cols")
)
def mpq_matmul(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    bits: jnp.ndarray,
    block_m: int = 16,
    block_rows: int = 32,
    block_cols: int = 32,
) -> jnp.ndarray:
    """y[M, N] = x[M, K] @ dequant(codes[N, K], scales, bits)^T."""
    M, K = x.shape
    N, K2 = codes.shape
    assert K == K2
    bm, bn, bk = block_m, block_rows, block_cols
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=True,
    )(x, codes, scales, bits)
