"""Build-time synthetic corpus + probe tasks.

Substitution (DESIGN.md): we have no WikiText-2 / RedPajama, so we build
a synthetic language with enough structure that (a) a small transformer
learns something non-trivial, (b) quantization error maps to measurable
perplexity/accuracy deltas, and (c) the model develops the heterogeneous
channel sensitivity the paper exploits.

The corpus mixes three processes:
  1. Zipfian-marginal Markov chain ("text"): a sparse first-order chain
     whose stationary distribution is approximately Zipf(1.1).
  2. Induction patterns: segments `a b ... a b` where the second
     occurrence is predictable — trains induction heads, the classic
     source of a few highly sensitive channels.
  3. Arithmetic-mod patterns: `x y (x+y mod V') ...` triples.

Probe tasks ("zero-shot" analog): held-out sequences whose final token
is fully determined by the pattern; accuracy = P(top-1 == target) at the
answer position.
"""

from __future__ import annotations

import numpy as np

PATTERN_VOCAB = 64  # pattern tokens live in [0, PATTERN_VOCAB)


def make_markov_chain(vocab: int, rng: np.random.Generator, out_degree: int = 24):
    """Sparse row-stochastic transition matrix with Zipfian target mass."""
    zipf = 1.0 / np.arange(1, vocab + 1) ** 1.1
    zipf /= zipf.sum()
    trans = np.zeros((vocab, vocab), np.float64)
    for s in range(vocab):
        nbrs = rng.choice(vocab, size=out_degree, replace=False, p=zipf)
        w = rng.dirichlet(np.ones(out_degree) * 0.5)
        np.add.at(trans[s], nbrs, w)
        trans[s] /= trans[s].sum()
    return trans


def sample_markov(trans, n, rng, state=0):
    vocab = trans.shape[0]
    out = np.empty(n, np.int32)
    for i in range(n):
        state = rng.choice(vocab, p=trans[state])
        out[i] = state
    return out


def inject_patterns(tokens: np.ndarray, rng: np.random.Generator,
                    density: float = 0.15):
    """Overwrite random windows with induction / arithmetic patterns."""
    n = len(tokens)
    n_windows = int(n * density / 16)
    for _ in range(n_windows):
        start = int(rng.integers(0, n - 24))
        kind = int(rng.integers(0, 2))
        if kind == 0:  # induction: a b c ... a b c (period-3 repeat)
            a, b, c = rng.integers(0, PATTERN_VOCAB, 3)
            pat = np.tile([a, b, c], 8)[:20]
        else:  # arithmetic mod chains
            x, y = rng.integers(0, PATTERN_VOCAB, 2)
            pat = []
            for _ in range(7):
                z = (x + y) % PATTERN_VOCAB
                pat += [x, y, z]
                x, y = y, z
            pat = np.array(pat[:20])
        tokens[start:start + len(pat)] = pat
    return tokens


def make_corpus(vocab: int, n_tokens: int, seed: int, chain_seed: int = 7):
    """Sample a token stream from the language defined by `chain_seed`.

    The transition matrix (the "language") is fixed by chain_seed; the
    sampling path varies with `seed`, so train/calib/eval are disjoint
    held-out samples of the SAME distribution.
    """
    chain_rng = np.random.default_rng(chain_seed)
    trans = make_markov_chain(vocab, chain_rng)
    rng = np.random.default_rng(seed)
    toks = sample_markov(trans, n_tokens, rng, state=int(rng.integers(0, vocab)))
    toks = inject_patterns(toks, rng)
    return toks.astype(np.int32)


def make_probe_tasks(seq_len: int, n_tasks: int, seed: int):
    """Sequences whose LAST token is pattern-determined.

    Returns (tokens [n, seq_len] with the answer in the final slot,
    answer_pos = seq_len - 1). Accuracy metric: model's top-1 prediction
    at position answer_pos - 1 must equal tokens[:, answer_pos].
    """
    rng = np.random.default_rng(seed)
    out = np.zeros((n_tasks, seq_len), np.int32)
    for i in range(n_tasks):
        # background: mild noise from the pattern vocab
        out[i] = rng.integers(0, PATTERN_VOCAB, seq_len)
        if i % 2 == 0:  # induction probe: ...a b c ... a b -> c
            a, b, c = rng.integers(0, PATTERN_VOCAB, 3)
            pat = np.tile([a, b, c], 6)
            out[i, -len(pat) - 1:-1] = pat  # ends mid-cycle
            k = (len(pat)) % 3
            nxt = [a, b, c][k]
            out[i, -1] = nxt
        else:  # arithmetic probe: x y (x+y) repeated, answer next elt
            x, y = rng.integers(0, PATTERN_VOCAB, 2)
            seq = []
            for _ in range(8):
                z = (x + y) % PATTERN_VOCAB
                seq += [int(x), int(y), int(z)]
                x, y = y, z
            seq = seq[:17]
            out[i, -len(seq) - 1:-1] = seq
            j = len(seq) % 3
            # next element after seq[:17]: continue the triple stream
            # recompute stream to position 17
            x, y = seq[0], seq[1]
            stream = [x, y]
            while len(stream) < 18:
                stream.append((stream[-2] + stream[-1]) % PATTERN_VOCAB)
            out[i, -1] = stream[17]
    return out
