"""AOT build: train the model, export datasets, weights, golden vectors,
and lower every computation graph to HLO TEXT for the rust runtime.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (artifacts/):
  manifest.json      — config, parameter table, executable signatures
  weights.bin        — trained f32 weights, manifest order, little-endian
  calib.bin/eval.bin — int32 token streams (calibration / held-out)
  tasks.bin          — probe-task sequences (int32 [n, seq_len])
  *.hlo.txt          — qloss, qgrad, qlogits{,_b1}, grams,
                       mpq_matmul, dense_matmul, elemmp_matmul
  golden.json        — cross-layer golden vectors (rust unit tests)

Run: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from .kernels.mpq_matmul import mpq_matmul
from .kernels.ref import quant_codes_ref, rtn_block_fakequant_ref
from .model import ModelConfig, graph_arg_specs, make_graphs
from .train import train

KERNEL_M, KERNEL_N, KERNEL_K = 16, 512, 512  # Table-4 analog GEMM shape


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_write(fn, specs, path: str) -> None:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB, {time.time() - t0:.1f}s)",
          flush=True)


# ---------------------------------------------------------------------
# kernel-bench graphs (Table 4 analog)


def dense_matmul(x, w):
    """BF16-baseline analog: plain f32 GEMM at the same shape."""
    return (x @ w.T,)


def mpq_matmul_graph(x, codes, scales, bits):
    return (mpq_matmul(x, codes, scales, bits),)


def elemmp_matmul(x, wq, idx, vals):
    """Unstructured element-wise MP baseline: scatter ~1% FP corrections
    into the dequantized weight, then GEMM. Models the irregular-access
    overhead of SpQR/SqueezeLLM-style element MP that the paper's
    block-wise design avoids."""
    w = wq.at[idx[:, 0], idx[:, 1]].set(vals)
    return (x @ w.T,)


# ---------------------------------------------------------------------


def export(out_dir: str, steps: int, quick: bool) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = ModelConfig()
    if quick:
        cfg = ModelConfig(n_layers=2, seq_len=64)
    batch = 8

    # ---- data ------------------------------------------------------
    print("[1/5] synthesizing corpus", flush=True)
    n_train = 60_000 if quick else 400_000
    corpus = data_mod.make_corpus(cfg.vocab, n_train, seed=7)
    calib = data_mod.make_corpus(cfg.vocab, 64_000, seed=11)
    evals = data_mod.make_corpus(cfg.vocab, 48_000, seed=13)
    tasks = data_mod.make_probe_tasks(cfg.seq_len, 256, seed=17)
    corpus.tofile(os.path.join(out_dir, "train.bin"))
    calib.tofile(os.path.join(out_dir, "calib.bin"))
    evals.tofile(os.path.join(out_dir, "eval.bin"))
    tasks.tofile(os.path.join(out_dir, "tasks.bin"))

    # ---- train (or reuse cached weights) ----------------------------
    names = cfg.param_names()
    weights_path = os.path.join(out_dir, "weights.bin")
    expected = sum(int(np.prod(cfg.param_shape(n))) for n in names)
    reuse = (not os.environ.get("SCALEBITS_RETRAIN")
             and os.path.exists(weights_path)
             and os.path.getsize(weights_path) == expected * 4)
    if reuse:
        print("[2/5] reusing cached trained weights "
              "(set SCALEBITS_RETRAIN=1 to force retraining)", flush=True)
        flat = np.fromfile(weights_path, dtype=np.float32)
        params = {}
        off = 0
        for n in names:
            shape = cfg.param_shape(n)
            size = int(np.prod(shape))
            params[n] = jnp.asarray(flat[off:off + size].reshape(shape))
            off += size
        final_loss = -1.0  # sentinel: weights reused, no fresh loss (NaN is not valid JSON)
    else:
        print(f"[2/5] training MiniLlama ({cfg.n_layers}L d{cfg.d_model}) "
              f"for {steps} steps", flush=True)
        result = train(cfg, corpus, steps=steps, seed=0)
        params = result["params"]
        final_loss = result["losses"][-1]
        flat = np.concatenate(
            [np.asarray(params[n], np.float32).ravel() for n in names])
        flat.tofile(weights_path)

    # ---- manifest ---------------------------------------------------
    print("[3/5] writing manifest + golden vectors", flush=True)
    qnames = cfg.quantized_names()
    offset = 0
    param_table = []
    for n in names:
        shape = list(cfg.param_shape(n))
        size = int(np.prod(shape))
        param_table.append({
            "name": n, "shape": shape, "offset": offset,
            "quantized": n in qnames,
        })
        offset += size

    sig = (["tokens"] + [f"bits:{n}" for n in qnames]
           + [f"param:{n}" for n in names])
    gram_sites = []
    for i in range(cfg.n_layers):
        gram_sites += [
            {"site": f"layers.{i}.attn_in", "dim": cfg.d_model,
             "consumers": [f"layers.{i}.{w}" for w in ("wq", "wk", "wv")]},
            {"site": f"layers.{i}.wo_in", "dim": cfg.d_model,
             "consumers": [f"layers.{i}.wo"]},
            {"site": f"layers.{i}.mlp_in", "dim": cfg.d_model,
             "consumers": [f"layers.{i}.w_gate", f"layers.{i}.w_up"]},
            {"site": f"layers.{i}.down_in", "dim": cfg.d_ff,
             "consumers": [f"layers.{i}.w_down"]},
        ]
    manifest = {
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
            "block_rows": cfg.block_rows, "block_cols": cfg.block_cols,
            "rope_theta": cfg.rope_theta,
        },
        "params": param_table,
        "quantized": qnames,
        "n_blocks": cfg.n_blocks(),
        "executables": {
            "qloss": {"file": "qloss.hlo.txt", "batch": batch,
                      "inputs": sig, "outputs": ["loss"]},
            "qgrad": {"file": "qgrad.hlo.txt", "batch": batch,
                      "inputs": sig,
                      "outputs": ["loss"] + [f"grad:{n}" for n in qnames]},
            "qlogits": {"file": "qlogits.hlo.txt", "batch": batch,
                        "inputs": sig, "outputs": ["logits"]},
            "qlogits_b1": {"file": "qlogits_b1.hlo.txt", "batch": 1,
                           "inputs": sig, "outputs": ["logits"]},
            "qpredict": {"file": "qpredict.hlo.txt", "batch": batch,
                         "inputs": sig, "outputs": ["pred"]},
            "grams": {"file": "grams.hlo.txt", "batch": batch,
                      "inputs": sig,
                      "outputs": ["loss"] + [g["site"] for g in gram_sites]},
        },
        "gram_sites": gram_sites,
        "kernel_bench": {
            "m": KERNEL_M, "n": KERNEL_N, "k": KERNEL_K,
            "block_rows": cfg.block_rows, "block_cols": cfg.block_cols,
            "files": {
                "mpq": "mpq_matmul.hlo.txt",
                "dense": "dense_matmul.hlo.txt",
                "elemmp": "elemmp_matmul.hlo.txt",
            },
            "elemmp_n_outliers": (KERNEL_N * KERNEL_K) // 100,
        },
        "datasets": {
            "train": {"file": "train.bin", "n_tokens": int(len(corpus))},
            "calib": {"file": "calib.bin", "n_tokens": int(len(calib))},
            "eval": {"file": "eval.bin", "n_tokens": int(len(evals))},
            "tasks": {"file": "tasks.bin", "n": int(tasks.shape[0]),
                      "seq_len": int(tasks.shape[1])},
        },
        "train_info": {"steps": steps, "final_loss": final_loss},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # ---- golden vectors (rust <-> python cross-validation) ---------
    rng = np.random.default_rng(3)
    gw = rng.standard_normal((64, 64)).astype(np.float32)
    gbits = rng.integers(0, 10, size=(2, 2)).astype(np.int32)
    gq = np.asarray(rtn_block_fakequant_ref(
        jnp.array(gw), jnp.array(gbits), 32, 32))
    codes4, scales4 = quant_codes_ref(gw, 4, 32)
    golden = {
        "fakequant": {
            "w": gw.ravel().tolist(), "rows": 64, "cols": 64,
            "bits": gbits.ravel().tolist(),
            "block_rows": 32, "block_cols": 32,
            "out": gq.ravel().tolist(),
        },
        "codes4": {
            "w": gw.ravel().tolist(), "rows": 64, "cols": 64, "group": 32,
            "codes": codes4.astype(int).ravel().tolist(),
            "scales": scales4.ravel().tolist(),
        },
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)

    # ---- lower model graphs -----------------------------------------
    print("[4/5] lowering model graphs to HLO text", flush=True)
    graphs = make_graphs(cfg)
    specs = graph_arg_specs(cfg, batch)
    specs_b1 = graph_arg_specs(cfg, 1)
    lower_and_write(graphs["qloss"], specs, os.path.join(out_dir, "qloss.hlo.txt"))
    lower_and_write(graphs["qgrad"], specs, os.path.join(out_dir, "qgrad.hlo.txt"))
    lower_and_write(graphs["qlogits"], specs, os.path.join(out_dir, "qlogits.hlo.txt"))
    lower_and_write(graphs["qlogits"], specs_b1,
                    os.path.join(out_dir, "qlogits_b1.hlo.txt"))
    lower_and_write(graphs["qpredict"], specs,
                    os.path.join(out_dir, "qpredict.hlo.txt"))
    lower_and_write(graphs["grams"], specs, os.path.join(out_dir, "grams.hlo.txt"))

    # ---- lower kernel-bench graphs ----------------------------------
    print("[5/5] lowering kernel-bench graphs", flush=True)
    f32 = jnp.float32
    br, bc = cfg.block_rows, cfg.block_cols
    x_s = jax.ShapeDtypeStruct((KERNEL_M, KERNEL_K), f32)
    codes_s = jax.ShapeDtypeStruct((KERNEL_N, KERNEL_K), jnp.int8)
    scales_s = jax.ShapeDtypeStruct((KERNEL_N, KERNEL_K // bc), f32)
    bits_s = jax.ShapeDtypeStruct((KERNEL_N // br, KERNEL_K // bc), jnp.int32)
    w_s = jax.ShapeDtypeStruct((KERNEL_N, KERNEL_K), f32)
    n_out = (KERNEL_N * KERNEL_K) // 100
    idx_s = jax.ShapeDtypeStruct((n_out, 2), jnp.int32)
    val_s = jax.ShapeDtypeStruct((n_out,), f32)

    lower_and_write(mpq_matmul_graph, [x_s, codes_s, scales_s, bits_s],
                    os.path.join(out_dir, "mpq_matmul.hlo.txt"))
    lower_and_write(dense_matmul, [x_s, w_s],
                    os.path.join(out_dir, "dense_matmul.hlo.txt"))
    lower_and_write(elemmp_matmul, [x_s, w_s, idx_s, val_s],
                    os.path.join(out_dir, "elemmp_matmul.hlo.txt"))

    print("AOT export complete:", out_dir, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--quick", action="store_true",
                    help="tiny config for CI smoke runs")
    args = ap.parse_args()
    export(args.out, args.steps, args.quick)


if __name__ == "__main__":
    main()
