"""L2: JAX transformer (MiniLlama) + the exported computation graphs.

Architecture mirrors the Llama recipe the paper quantizes (RMSNorm, RoPE,
MHA, SwiGLU), at a build-time-trainable scale. The rust coordinator
NEVER sees this code — it consumes the lowered HLO artifacts plus
`manifest.json`, which pins the exact positional parameter order used
here.

Canonical parameter order (manifest order; Q marks quantized matrices):

  embed                       [V, D]
  layers.i.attn_norm          [D]
  layers.i.wq    Q            [D, D]
  layers.i.wk    Q            [D, D]
  layers.i.wv    Q            [D, D]
  layers.i.wo    Q            [D, D]
  layers.i.mlp_norm           [D]
  layers.i.w_gate Q           [F, D]
  layers.i.w_up   Q           [F, D]
  layers.i.w_down Q           [D, F]
  final_norm                  [D]
  lm_head                     [V, D]

All linears are `y = x @ W^T` with W stored [out, in], matching the
paper's d_out x d_in convention (rows = output channels, cols = input
channels).

Exported graphs (see aot.py):
  qloss   (tokens, *bits, *params) -> loss
  qgrad   (tokens, *bits, *params) -> (loss, *grads at the quantized point)
  qlogits (tokens, *bits, *params) -> logits
  grams   (tokens, *bits, *params) -> (*X^T X per linear-input site)

`bits` carries one int32 grid per quantized matrix; entries >= 9 mean
"full precision", so a single artifact covers FP baseline, uniform RTN
and mixed-precision paths. Q(w, b) is applied on-device via the L1
Pallas kernel, and gradients are taken AT THE QUANTIZED POINT w^Q
(paper Eq. 3) by differentiating wrt the already-fake-quantized weights.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.rtn_block_fakequant import rtn_block_fakequant

QUANT_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    seq_len: int = 128
    block_rows: int = 32
    block_cols: int = 32
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    # ---- parameter registry ---------------------------------------

    def param_names(self) -> List[str]:
        names = ["embed"]
        for i in range(self.n_layers):
            p = f"layers.{i}."
            names += [p + "attn_norm", p + "wq", p + "wk", p + "wv", p + "wo",
                      p + "mlp_norm", p + "w_gate", p + "w_up", p + "w_down"]
        names += ["final_norm", "lm_head"]
        return names

    def param_shape(self, name: str) -> Tuple[int, ...]:
        V, D, F = self.vocab, self.d_model, self.d_ff
        leaf = name.split(".")[-1]
        return {
            "embed": (V, D), "lm_head": (V, D),
            "attn_norm": (D,), "mlp_norm": (D,), "final_norm": (D,),
            "wq": (D, D), "wk": (D, D), "wv": (D, D), "wo": (D, D),
            "w_gate": (F, D), "w_up": (F, D), "w_down": (D, F),
        }[leaf]

    def quantized_names(self) -> List[str]:
        return [n for n in self.param_names() if n.split(".")[-1] in QUANT_NAMES]

    def bits_shape(self, name: str) -> Tuple[int, int]:
        r, c = self.param_shape(name)
        return (r // self.block_rows, c // self.block_cols)

    def n_blocks(self) -> int:
        return sum(int(np.prod(self.bits_shape(n))) for n in self.quantized_names())


# ---------------------------------------------------------------------
# parameter helpers


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    params = {}
    for name in cfg.param_names():
        shape = cfg.param_shape(name)
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / np.sqrt(fan_in)
            )
    return params


def params_to_list(cfg: ModelConfig, params: Dict[str, jnp.ndarray]):
    return [params[n] for n in cfg.param_names()]


def list_to_params(cfg: ModelConfig, lst) -> Dict[str, jnp.ndarray]:
    return dict(zip(cfg.param_names(), lst))


# ---------------------------------------------------------------------
# model blocks


def rmsnorm(x, g, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope(x, theta: float):
    """x: [B, T, H, Hd]; rotate pairs (even, odd) of the head dim."""
    B, T, H, Hd = x.shape
    half = Hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = jnp.arange(T, dtype=jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos[None, :, None, :] - x2 * sin[None, :, None, :]
    rx2 = x1 * sin[None, :, None, :] + x2 * cos[None, :, None, :]
    return jnp.concatenate([rx1, rx2], axis=-1)


def attention(cfg: ModelConfig, x, wq, wk, wv, wo, collect=None):
    B, T, D = x.shape
    H, Hd = cfg.n_heads, cfg.head_dim
    q = (x @ wq.T).reshape(B, T, H, Hd)
    k = (x @ wk.T).reshape(B, T, H, Hd)
    v = (x @ wv.T).reshape(B, T, H, Hd)
    q, k = rope(q, cfg.rope_theta), rope(k, cfg.rope_theta)
    att = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(Hd)
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, D)
    if collect is not None:
        collect.append(out)  # input of wo
    return out @ wo.T


def mlp(x, w_gate, w_up, w_down, collect=None):
    h = jax.nn.silu(x @ w_gate.T) * (x @ w_up.T)
    if collect is not None:
        collect.append(h)  # input of w_down
    return h @ w_down.T


def forward(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens,
            collect_inputs: bool = False):
    """tokens [B, T] int32 -> logits [B, T, V] (+ optional linear inputs).

    collect_inputs gathers the activation entering each linear-input
    site, in order (attn_in, wo_in, mlp_in, down_in) per layer — the
    inputs whose Grams the GPTQ baseline needs.
    """
    sites = [] if collect_inputs else None
    x = params["embed"][tokens]  # [B, T, D]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rmsnorm(x, params[p + "attn_norm"])
        if sites is not None:
            sites.append(h)  # input of wq/wk/wv
        x = x + attention(cfg, h, params[p + "wq"], params[p + "wk"],
                          params[p + "wv"], params[p + "wo"], collect=sites)
        h = rmsnorm(x, params[p + "mlp_norm"])
        if sites is not None:
            sites.append(h)  # input of w_gate/w_up
        x = x + mlp(h, params[p + "w_gate"], params[p + "w_up"],
                    params[p + "w_down"], collect=sites)
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["lm_head"].T
    if collect_inputs:
        # reorder per layer to (attn_in, wo_in, mlp_in, down_in)
        per_layer = []
        for i in range(cfg.n_layers):
            attn_in, wo_in, mlp_in, down_in = (
                sites[4 * i], sites[4 * i + 1], sites[4 * i + 2], sites[4 * i + 3])
            per_layer += [attn_in, wo_in, mlp_in, down_in]
        return logits, per_layer
    return logits


def ce_loss(logits, tokens):
    """Next-token cross entropy, mean over positions."""
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------
# quantized graphs


def fakequant_params(cfg: ModelConfig, params, bits_list):
    """Apply the L1 Pallas kernel Q(w, b) to every quantized matrix."""
    qnames = cfg.quantized_names()
    out = dict(params)
    for name, bits in zip(qnames, bits_list):
        out[name] = rtn_block_fakequant(
            params[name], bits, cfg.block_rows, cfg.block_cols)
    return out


def make_graphs(cfg: ModelConfig):
    """Build the 4 exported computations as positional-arg functions."""
    names = cfg.param_names()
    qnames = cfg.quantized_names()
    nq = len(qnames)

    def unpack(args):
        tokens = args[0]
        bits_list = list(args[1:1 + nq])
        params = dict(zip(names, args[1 + nq:]))
        return tokens, bits_list, params

    def qloss(*args):
        tokens, bits_list, params = unpack(args)
        qp = fakequant_params(cfg, params, bits_list)
        return (ce_loss(forward(cfg, qp, tokens), tokens),)

    def qlogits(*args):
        tokens, bits_list, params = unpack(args)
        qp = fakequant_params(cfg, params, bits_list)
        return (forward(cfg, qp, tokens),)

    def qpredict(*args):
        # Serving/eval fast path: top-1 prediction per position. Returns
        # [B, T] int32 instead of [B, T, V] f32 logits — 512x less
        # device->host traffic (EXPERIMENTS.md §Perf iteration 3).
        tokens, bits_list, params = unpack(args)
        qp = fakequant_params(cfg, params, bits_list)
        logits = forward(cfg, qp, tokens)
        return (jnp.argmax(logits, axis=-1).astype(jnp.int32),)

    def qgrad(*args):
        tokens, bits_list, params = unpack(args)
        qp = fakequant_params(cfg, params, bits_list)
        qmats = tuple(qp[n] for n in qnames)

        def loss_at(qmats_):
            p = dict(qp)
            p.update(zip(qnames, qmats_))
            return ce_loss(forward(cfg, p, tokens), tokens)

        # Gradient AT the quantized point w^Q (paper Eq. 3) — the
        # fake-quant op is outside the differentiation scope, so no
        # straight-through estimator is involved.
        loss, grads = jax.value_and_grad(loss_at)(qmats)
        return (loss, *grads)

    def grams(*args):
        tokens, bits_list, params = unpack(args)
        qp = fakequant_params(cfg, params, bits_list)
        logits, sites = forward(cfg, qp, tokens, collect_inputs=True)
        outs = []
        for s in sites:  # [B, T, d] -> [d, d]
            flat = s.reshape(-1, s.shape[-1])
            outs.append(flat.T @ flat)
        # The loss output keeps EVERY parameter live (lm_head, final
        # norm, the last w_down): without it XLA prunes the unused
        # inputs and the executable signature no longer matches the
        # manifest's positional argument list.
        return (ce_loss(logits, tokens), *outs)

    return {
        "qloss": qloss,
        "qgrad": qgrad,
        "qlogits": qlogits,
        "qpredict": qpredict,
        "grams": grams,
    }


def graph_arg_specs(cfg: ModelConfig, batch: int):
    """ShapeDtypeStructs for the shared (tokens, *bits, *params) signature."""
    specs = [jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)]
    for n in cfg.quantized_names():
        specs.append(jax.ShapeDtypeStruct(cfg.bits_shape(n), jnp.int32))
    for n in cfg.param_names():
        specs.append(jax.ShapeDtypeStruct(cfg.param_shape(n), jnp.float32))
    return specs
