"""Build-time training of the MiniLlama on the synthetic corpus.

This runs ONCE inside `make artifacts` (python is never on the request
path). A few hundred Adam steps are enough to (a) drive the loss well
below the unigram entropy, (b) grow induction behaviour (probe accuracy
>> 1/64 chance), and (c) develop the non-uniform channel sensitivity
that ScaleBITS exploits.
"""

from __future__ import annotations

import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .model import ModelConfig, ce_loss, forward, init_params


def adam_init(params):
    z = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def train_step(cfg: ModelConfig, params, opt, tokens, lr=3e-3):
    def loss_fn(p):
        return ce_loss(forward(cfg, p, tokens), tokens)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    b1, b2, eps = 0.9, 0.95, 1e-8
    t = opt["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * opt["m"][k] + (1 - b1) * grads[k]
        v = b2 * opt["v"][k] + (1 - b2) * grads[k] ** 2
        mhat = m / (1 - b1 ** t.astype(jnp.float32))
        vhat = v / (1 - b2 ** t.astype(jnp.float32))
        new_m[k], new_v[k] = m, v
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, {"m": new_m, "v": new_v, "t": t}, loss


def batches(corpus: np.ndarray, batch: int, seq_len: int, rng: np.random.Generator):
    n = len(corpus) - seq_len - 1
    while True:
        idx = rng.integers(0, n, batch)
        yield np.stack([corpus[i:i + seq_len] for i in idx])


def train(cfg: ModelConfig, corpus: np.ndarray, steps: int = 400,
          batch: int = 16, seed: int = 0, log_every: int = 50) -> Dict:
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    rng = np.random.default_rng(seed + 1)
    it = batches(corpus, batch, cfg.seq_len, rng)
    losses = []
    t0 = time.time()
    for step in range(steps):
        toks = jnp.asarray(next(it))
        params, opt, loss = train_step(cfg, params, opt, toks)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"  train step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return {"params": params, "losses": losses}
