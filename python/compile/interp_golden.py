"""Golden generator for the pure-Rust interpreter backend.

Mirrors, bit-for-bit, the synthetic model that `model::synth` builds on
the rust side (same Xoshiro256++/SplitMix64 RNG, same transcendental-
free uniform weight init, same token streams), fake-quantizes in
float32 exactly like the rust RTN mirror, runs the MiniLlama forward
pass in float64 numpy, and records the cross-entropy losses to
`rust/tests/data/interp_golden.json`.

The rust test `interp_qloss_matches_python_golden` rebuilds the same
synthetic model from the spec in the JSON and asserts the interpreter
loss matches within 1e-4 (observed agreement is ~1e-10; the tolerance
only absorbs f32 rounding of the returned scalar and summation-order
differences between numpy's BLAS and the interpreter's loops).

Run: cd python && python -m compile.interp_golden
(needs numpy only — no JAX, no artifacts)
"""

from __future__ import annotations

import json
import os

import numpy as np

MASK64 = (1 << 64) - 1

# Recorded into the golden JSON as "token_seed_xor"; the rust test
# reads it from there, so this constant is the single source of truth.
GOLDEN_TOKENS_XOR = 0x601D

SPEC = {
    "vocab": 64,
    "d_model": 32,
    "n_layers": 2,
    "n_heads": 2,
    "d_ff": 64,
    "seq_len": 32,
    "block_rows": 16,
    "block_cols": 16,
    "batch": 4,
    "seed": 7,
}

ROPE_THETA = 10000.0
RMS_EPS = 1e-5


# ---------------------------------------------------------------------
# rust RNG mirror (util/rng.rs): SplitMix64 -> Xoshiro256++


class Rng:
    def __init__(self, seed: int):
        state = seed & MASK64
        s = []
        for _ in range(4):
            state = (state + 0x9E3779B97F4A7C15) & MASK64
            z = state
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
            s.append(z ^ (z >> 31))
        self.s = s

    @staticmethod
    def _rotl(x: int, k: int) -> int:
        return ((x << k) | (x >> (64 - k))) & MASK64

    def next_u64(self) -> int:
        s = self.s
        result = (self._rotl((s[0] + s[3]) & MASK64, 23) + s[0]) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return result

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n: int) -> int:
        """Lemire's unbiased bounded integer (mirror of Rng::below)."""
        x = self.next_u64()
        m = x * n
        lo = m & MASK64
        if lo < n:
            t = (1 << 64) % n
            while lo < t:
                x = self.next_u64()
                m = x * n
                lo = m & MASK64
        return m >> 64


# ---------------------------------------------------------------------
# synthetic model mirror (model/synth.rs)


def param_names(spec):
    names = ["embed"]
    for i in range(spec["n_layers"]):
        for leaf in ("attn_norm", "wq", "wk", "wv", "wo",
                     "mlp_norm", "w_gate", "w_up", "w_down"):
            names.append(f"layers.{i}.{leaf}")
    names += ["final_norm", "lm_head"]
    return names


def param_shape(spec, name):
    v, d, f = spec["vocab"], spec["d_model"], spec["d_ff"]
    leaf = name.rsplit(".", 1)[-1]
    return {
        "embed": (v, d), "lm_head": (v, d),
        "attn_norm": (d,), "mlp_norm": (d,), "final_norm": (d,),
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w_gate": (f, d), "w_up": (f, d), "w_down": (d, f),
    }[leaf]


QUANT_LEAVES = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"}


def weight_store(spec):
    rng = Rng(spec["seed"])
    params = {}
    for name in param_names(spec):
        shape = param_shape(spec, name)
        if len(shape) == 1:
            params[name] = np.ones(shape, np.float32)
        else:
            fan_in = shape[-1]
            a = np.sqrt(3.0 / fan_in)  # python float == f64, like rust
            n = int(np.prod(shape))
            vals = np.empty(n, np.float32)
            for i in range(n):
                vals[i] = np.float32((rng.f64() * 2.0 - 1.0) * a)
            params[name] = vals.reshape(shape)
    return params


def token_stream(n, vocab, seed):
    rng = Rng(seed)
    return np.array([rng.below(vocab) for _ in range(n)], np.int32)


# ---------------------------------------------------------------------
# float32 RTN fake-quant (mirror of quant::fakequant_group, bits >= 2)


def fakequant(w, bits, block_cols):
    if bits >= 9:
        return w.copy()
    if bits <= 0:
        return np.zeros_like(w)
    assert bits >= 2, "1-bit golden not generated (summation-order sensitive)"
    r, c = w.shape
    g = w.reshape(r, c // block_cols, block_cols)
    qmax = np.float32(2.0 ** (bits - 1) - 1.0)
    amax = np.max(np.abs(g), axis=-1, keepdims=True)
    scale = (amax / max(qmax, np.float32(1.0))).astype(np.float32)
    safe = np.where(scale > 0, scale, np.float32(1.0))
    q = np.clip(np.round(g / safe), -qmax, qmax).astype(np.float32)
    return (q * scale).astype(np.float32).reshape(r, c)


# ---------------------------------------------------------------------
# float64 MiniLlama forward (mirror of runtime/interp.rs)


def rmsnorm(x, g):
    var = np.mean(x * x, axis=-1, keepdims=True)
    return x / np.sqrt(var + RMS_EPS) * g


def rope(x):
    b, t, h, hd = x.shape
    half = hd // 2
    freqs = ROPE_THETA ** (-np.arange(half, dtype=np.float64) / half)
    ang = np.arange(t, dtype=np.float64)[:, None] * freqs[None, :]
    cos, sin = np.cos(ang), np.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos[None, :, None, :] - x2 * sin[None, :, None, :]
    rx2 = x1 * sin[None, :, None, :] + x2 * cos[None, :, None, :]
    return np.concatenate([rx1, rx2], axis=-1)


def softmax(a, axis=-1):
    a = a - a.max(axis=axis, keepdims=True)
    e = np.exp(a)
    return e / e.sum(axis=axis, keepdims=True)


def silu(z):
    return z / (1.0 + np.exp(-z))


def forward(spec, params, tokens):
    b, t = tokens.shape
    d, h = spec["d_model"], spec["n_heads"]
    hd = d // h
    x = params["embed"][tokens]  # [B, T, D] float64
    for i in range(spec["n_layers"]):
        p = f"layers.{i}."
        hh = rmsnorm(x, params[p + "attn_norm"])
        q = (hh @ params[p + "wq"].T).reshape(b, t, h, hd)
        k = (hh @ params[p + "wk"].T).reshape(b, t, h, hd)
        v = (hh @ params[p + "wv"].T).reshape(b, t, h, hd)
        q, k = rope(q), rope(k)
        att = np.einsum("bthd,bshd->bhts", q, k) / np.sqrt(hd)
        mask = np.tril(np.ones((t, t), bool))
        att = np.where(mask[None, None], att, -1e30)
        att = softmax(att, axis=-1)
        out = np.einsum("bhts,bshd->bthd", att, v).reshape(b, t, d)
        x = x + out @ params[p + "wo"].T
        hh = rmsnorm(x, params[p + "mlp_norm"])
        hp = silu(hh @ params[p + "w_gate"].T) * (hh @ params[p + "w_up"].T)
        x = x + hp @ params[p + "w_down"].T
    x = rmsnorm(x, params["final_norm"])
    return x @ params["lm_head"].T


def ce_loss(logits, tokens):
    lx = logits[:, :-1].astype(np.float64)
    m = lx.max(axis=-1, keepdims=True)
    lse = m[..., 0] + np.log(np.exp(lx - m).sum(axis=-1))
    tgt = tokens[:, 1:]
    picked = np.take_along_axis(lx, tgt[..., None].astype(np.int64), axis=-1)[..., 0]
    return float(np.mean(lse - picked))


# ---------------------------------------------------------------------


def main():
    spec = SPEC
    store = weight_store(spec)
    tokens = token_stream(
        spec["batch"] * spec["seq_len"], spec["vocab"],
        spec["seed"] ^ GOLDEN_TOKENS_XOR,
    ).reshape(spec["batch"], spec["seq_len"])

    cases = []
    for bits in (3, 4, 16):
        params = {}
        for name, w in store.items():
            leaf = name.rsplit(".", 1)[-1]
            if leaf in QUANT_LEAVES:
                wq = fakequant(w, bits, spec["block_cols"])
            else:
                wq = w
            params[name] = wq.astype(np.float64)
        logits = forward(spec, params, tokens)
        loss = ce_loss(logits, tokens)
        cases.append({"bits": bits, "loss": loss})
        print(f"bits={bits:2d}  qloss={loss:.12f}")

    out = {
        "spec": spec,
        "token_seed_xor": GOLDEN_TOKENS_XOR,
        "cases": cases,
    }
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "rust", "tests", "data", "interp_golden.json")
    path = os.path.normpath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
