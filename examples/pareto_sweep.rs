//! Pareto sweep (Figure 1 analog): trace the perplexity–bits frontier,
//! optionally annotated with SERVED decode throughput per operating
//! point (quality AND serving cost of each allocation, one csv).
//!
//! ScaleBITS reaches arbitrary budgets; uniform RTN only has discrete
//! points. The sweep writes results/pareto.csv for plotting. With
//! `--serve-requests N` (default 8, 0 disables) every operating point
//! is additionally served through the continuous-batching router for N
//! multi-token sessions and the measured decode tokens/sec lands in
//! the `serve_tps` column. A second serving pass per point runs with
//! self-speculative decoding on (`--serve-spec-k`, default 4, 0
//! disables): the `accept_rate` column is the draft accept-rate of the
//! 2-bit self-draft against that point's allocation and
//! `effective_tps` is the decode tok/s it actually yields.
//!
//! Run: cargo run --release --offline --example pareto_sweep
//!      [-- --points 5 --serve-requests 8 --serve-spec-k 4 --iters 100]

use std::io::Write;

use scalebits::coordinator::Pipeline;
use scalebits::quant::BitAlloc;
use scalebits::search::SearchConfig;
use scalebits::serve::{run_workload, Router, ServeConfig, WorkloadSpec};
use scalebits::util::cli::Args;

/// One operating point through the serving stack: plain decode tok/s,
/// then the same short-prompt workload again with self-speculative
/// decoding on — draft accept-rate and EFFECTIVE decode tok/s (what
/// the point actually yields once the 2-bit draft of the same weights
/// proposes and the mixed-precision target verifies). Prompts sit at
/// seq/2 so decode windows stay unslid and unfilled (drafting is only
/// eligible there); all zeros when serving is disabled.
fn served_point(
    artifacts: &std::path::Path,
    p: &Pipeline,
    alloc: &BitAlloc,
    n_requests: usize,
    spec_k: usize,
) -> anyhow::Result<(f64, f64, f64)> {
    if n_requests == 0 {
        return Ok((0.0, 0.0, 0.0));
    }
    let stream = scalebits::calib::TokenStream::from_manifest(p.manifest(), "eval")?;
    let p_len = (p.manifest().config.seq_len / 2).max(1);
    let mut run = |k: usize| -> anyhow::Result<(f64, f64)> {
        let mut cfg = ServeConfig::new(artifacts.to_path_buf(), alloc.clone());
        cfg.backend = p.backend.kind();
        cfg.spec_k = k;
        let mut server = Router::start(cfg)?;
        let spec = WorkloadSpec::new(p_len, n_requests, 200.0, 13).max_new_tokens(4);
        let wl = run_workload(&mut server, &stream, &spec)?;
        let rep = server.shutdown()?;
        Ok((wl.decode_tps(), rep.total.spec_accept_rate()))
    };
    let (tps, _) = run(0)?;
    if spec_k == 0 {
        return Ok((tps, 0.0, 0.0));
    }
    let (effective_tps, accept_rate) = run(spec_k)?;
    Ok((tps, accept_rate, effective_tps))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let points = args.usize_or("points", 7)?;
    let serve_requests = args.usize_or("serve-requests", 8)?;
    // self-speculative serving pass per point (0 skips it; the
    // accept_rate/effective_tps columns are then 0)
    let serve_spec_k = args.usize_or("serve-spec-k", 4)?;
    // search budget per operating point (the examples-smoke CI lane
    // passes a small value so the sweep finishes in seconds)
    let iters = args.usize_or("iters", SearchConfig::default().max_iters)?;
    // Artifact-less container (the ci.sh examples-smoke lane): with no
    // explicit --artifacts and no artifacts/ dir, synthesize the
    // deterministic model; BackendKind::Auto then resolves to the
    // interpreter (no HLO files present). An explicit path must exist.
    let artifacts = scalebits::model::synth::artifacts_or_synth(
        args.str_opt("artifacts").map(String::from),
        "example",
    )?;

    let mut p = Pipeline::load_full(&artifacts)?;
    let mut rows: Vec<(String, f64, f64, f64, f64, f64, f64)> = Vec::new();

    println!("== uniform RTN operating points ==");
    for bits in [2, 3, 4] {
        let alloc = BitAlloc::uniform(&p.index, bits);
        let r = p.eval_alloc(&alloc)?;
        let (tps, ar, etps) =
            served_point(&artifacts, &p, &alloc, serve_requests, serve_spec_k)?;
        println!(
            "  uniform {bits}b: ppl {:8.2}  acc {:5.1}%  serve {tps:7.1} tok/s  \
             accept {ar:4.2}  effective {etps:7.1} tok/s",
            r.perplexity,
            100.0 * r.task_accuracy
        );
        rows.push(("uniform".into(), r.avg_bits, r.perplexity, r.task_accuracy, tps, ar, etps));
    }

    println!("== ScaleBITS frontier ==");
    p.reorder(3, 42)?;
    for i in 0..points {
        let budget = 2.0 + 2.0 * i as f64 / (points - 1).max(1) as f64;
        let cfg = SearchConfig { budget, seed: 42, max_iters: iters, ..Default::default() };
        let res = p.search(&cfg)?;
        let r = p.eval_alloc(&res.alloc)?;
        let (tps, ar, etps) =
            served_point(&artifacts, &p, &res.alloc, serve_requests, serve_spec_k)?;
        println!(
            "  budget {budget:4.2}: avg {:4.2}b  ppl {:8.2}  acc {:5.1}%  serve {tps:7.1} tok/s  \
             accept {ar:4.2}  effective {etps:7.1} tok/s  ({} iters, {:.1}s)",
            r.avg_bits,
            r.perplexity,
            100.0 * r.task_accuracy,
            res.iters.len(),
            res.wall_secs
        );
        rows.push(("scalebits".into(), r.avg_bits, r.perplexity, r.task_accuracy, tps, ar, etps));
    }

    std::fs::create_dir_all("results")?;
    let mut f = std::fs::File::create("results/pareto.csv")?;
    writeln!(f, "method,bits,ppl,task_acc,serve_tps,accept_rate,effective_tps")?;
    for (m, b, ppl, acc, tps, ar, etps) in &rows {
        writeln!(f, "{m},{b:.3},{ppl:.4},{acc:.4},{tps:.2},{ar:.4},{etps:.2}")?;
    }
    println!("wrote results/pareto.csv ({} rows)", rows.len());
    Ok(())
}
