//! Pareto sweep (Figure 1 analog): trace the perplexity–bits frontier.
//!
//! ScaleBITS reaches arbitrary budgets; uniform RTN only has discrete
//! points. The sweep writes results/pareto.csv for plotting.
//!
//! Run: cargo run --release --offline --example pareto_sweep [-- --points 5]

use std::io::Write;

use scalebits::coordinator::Pipeline;
use scalebits::quant::BitAlloc;
use scalebits::search::SearchConfig;
use scalebits::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let points = args.usize_or("points", 7)?;
    let artifacts = std::path::PathBuf::from(args.str_or("artifacts", "artifacts"));

    let mut p = Pipeline::load_full(&artifacts)?;
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();

    println!("== uniform RTN operating points ==");
    for bits in [2, 3, 4] {
        let r = p.eval_alloc(&BitAlloc::uniform(&p.index, bits))?;
        println!("  uniform {bits}b: ppl {:8.2}  acc {:5.1}%", r.perplexity, 100.0 * r.task_accuracy);
        rows.push(("uniform".into(), r.avg_bits, r.perplexity, r.task_accuracy));
    }

    println!("== ScaleBITS frontier ==");
    p.reorder(3, 42)?;
    for i in 0..points {
        let budget = 2.0 + 2.0 * i as f64 / (points - 1).max(1) as f64;
        let cfg = SearchConfig { budget, seed: 42, ..Default::default() };
        let res = p.search(&cfg)?;
        let r = p.eval_alloc(&res.alloc)?;
        println!(
            "  budget {budget:4.2}: avg {:4.2}b  ppl {:8.2}  acc {:5.1}%  ({} iters, {:.1}s)",
            r.avg_bits,
            r.perplexity,
            100.0 * r.task_accuracy,
            res.iters.len(),
            res.wall_secs
        );
        rows.push(("scalebits".into(), r.avg_bits, r.perplexity, r.task_accuracy));
    }

    std::fs::create_dir_all("results")?;
    let mut f = std::fs::File::create("results/pareto.csv")?;
    writeln!(f, "method,bits,ppl,task_acc")?;
    for (m, b, ppl, acc) in &rows {
        writeln!(f, "{m},{b:.3},{ppl:.4},{acc:.4}")?;
    }
    println!("wrote results/pareto.csv ({} rows)", rows.len());
    Ok(())
}
