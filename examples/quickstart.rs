//! Quickstart: the full ScaleBITS pipeline on the bundled MiniLlama.
//!
//!   load artifacts -> baseline eval -> bi-directional channel reorder
//!   -> scalable greedy bitwidth search at a 2.5-bit budget -> eval ->
//!   packed-storage report.
//!
//! Run: cargo run --release --offline --example quickstart
//! (requires `make artifacts` first)

use scalebits::coordinator::Pipeline;
use scalebits::quant::{BitAlloc, PackedMat};
use scalebits::search::SearchConfig;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");
    let budget = 2.5;
    println!("== ScaleBITS quickstart (budget {budget} bits/weight) ==\n");

    println!("[load] compiling AOT executables (qloss/qgrad/qlogits) ...");
    let mut p = Pipeline::load_full(&artifacts)?;
    let c = p.manifest().config.clone();
    println!(
        "  MiniLlama: {} layers, d_model {}, {} quantizable blocks\n",
        c.n_layers, c.d_model, p.index.n_blocks
    );

    println!("[baseline] FP16 and uniform RTN ...");
    let fp = p.eval_alloc(&p.fp_alloc())?;
    println!("  fp16       : ppl {:6.2}  task acc {:5.1}%", fp.perplexity, 100.0 * fp.task_accuracy);
    let u2 = p.eval_alloc(&BitAlloc::uniform(&p.index, 2))?;
    println!("  uniform 2b : ppl {:6.2}  task acc {:5.1}%", u2.perplexity, 100.0 * u2.task_accuracy);
    let u3 = p.eval_alloc(&BitAlloc::uniform(&p.index, 3))?;
    println!("  uniform 3b : ppl {:6.2}  task acc {:5.1}%\n", u3.perplexity, 100.0 * u3.task_accuracy);

    println!("[reorder] bi-directional channel reordering ...");
    p.reorder(3, 42)?;
    println!("  done (functional equivalence verified)\n");

    println!("[search] scalable greedy, gamma 5% -> 2% ...");
    let cfg = SearchConfig { budget, seed: 42, verbose: true, ..Default::default() };
    let res = p.search(&cfg)?;
    println!(
        "  {} iterations ({} accepted) in {:.1}s, {} executable calls\n",
        res.iters.len(),
        res.accepted_iters(),
        res.wall_secs,
        res.exec_calls
    );

    println!("[eval] mixed-precision model at avg {:.2} bits ...", res.alloc.avg_bits());
    let r = p.eval_alloc(&res.alloc)?;
    println!("  ScaleBITS  : ppl {:6.2}  task acc {:5.1}%", r.perplexity, 100.0 * r.task_accuracy);
    println!(
        "  (vs uniform-2 ppl {:.2} / uniform-3 ppl {:.2} at budget {:.1})\n",
        u2.perplexity, u3.perplexity, budget
    );

    // Real packed export: how big is the quantized model on disk?
    let mut packed = 0usize;
    let mut fp16 = 0usize;
    for (mi, name) in p.index.mats.iter().enumerate() {
        let w = p.store.get(name)?;
        let grid = &res.alloc.bits[p.index.mat_range(mi)];
        packed += PackedMat::quantize(w, grid, p.index.block_rows, p.index.block_cols)
            .storage_bytes();
        fp16 += w.data.len() * 2;
    }
    println!(
        "[pack] quantized weights: {:.2} MiB vs bf16 {:.2} MiB  ({:.2}x smaller)",
        packed as f64 / (1 << 20) as f64,
        fp16 as f64 / (1 << 20) as f64,
        fp16 as f64 / packed as f64
    );
    Ok(())
}
