//! Ablation suite runner: reproduces the Appendix E ablations (Fig 15,
//! Fig 16) in one shot, on the bundled artifacts.
//!
//! Run: cargo run --release --offline --example ablation_suite

use scalebits::coordinator::{experiments_ablation as ab, Pipeline};
use scalebits::runtime::BackendKind;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");
    println!("== ablation: adaptive gradients + channel reordering (Fig 15) ==");
    ab::fig15(&artifacts, BackendKind::Auto, 42)?;
    println!("\n== ablation: sensitivity statistics for one-sided updates (Fig 16) ==");
    let mut p = Pipeline::load_full(&artifacts)?;
    ab::fig16(&mut p, 42)?;
    Ok(())
}
