//! Serving demo: batched next-token service over the quantized model,
//! through the multi-worker router.
//!
//! Demonstrates the paper's §5.3 claim end-to-end: a MIXED-precision
//! bit allocation served through the same compiled executable has the
//! same latency as a uniform one at equal average bits — the request
//! path never branches on precision. The worker sweep additionally
//! shows the scaling the router buys: each worker owns its own PJRT
//! engine with device-resident weights and bit grids, so adding
//! workers multiplies capacity without touching the request path.
//!
//! Run: cargo run --release --offline --example serve_quantized
//!      [-- --requests 24 --rate 100 --workers 4]

use scalebits::calib::TokenStream;
use scalebits::model::Manifest;
use scalebits::quant::{BitAlloc, BlockIndex};
use scalebits::serve::{run_workload, Router, ServeConfig};
use scalebits::util::cli::Args;
use scalebits::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.usize_or("requests", 24)?;
    let rate = args.f64_or("rate", 100.0)?;
    let max_workers = args.usize_or("workers", 4)?;
    let artifacts = std::path::PathBuf::from(args.str_or("artifacts", "artifacts"));

    let m = Manifest::load(&artifacts)?;
    let index = BlockIndex::from_manifest(&m)?;
    let stream = TokenStream::from_manifest(&m, "eval")?;
    let seq = m.config.seq_len;

    // Allocation A: uniform 4-bit. Allocation B: mixed 2/4/8 at avg 4.
    let uniform = BitAlloc::uniform(&index, 4);
    let mut mixed = BitAlloc::uniform(&index, 4);
    let mut rng = Rng::new(9);
    for b in mixed.bits.iter_mut() {
        *b = match rng.below(10) {
            0..=3 => 2,
            4..=7 => 4,
            _ => 8,
        };
    }
    println!(
        "uniform avg bits {:.2} | mixed avg bits {:.2} (40% INT2 / 40% INT4 / 20% INT8)",
        uniform.avg_bits(),
        mixed.avg_bits()
    );

    let sweeps: Vec<usize> = if max_workers > 1 { vec![1, max_workers] } else { vec![1] };
    for (label, alloc) in [("uniform-4bit", uniform), ("mixed-2/4/8", mixed)] {
        for &workers in &sweeps {
            let mut cfg = ServeConfig::new(artifacts.clone(), alloc.clone());
            cfg.workers = workers;
            let mut server = Router::start(cfg)?;
            let wl = run_workload(&mut server, &stream, seq, n, rate, 7)?;
            let report = server.shutdown()?;
            println!(
                "{} | {:.1} req/s, {} batches, occupancy {:.2}",
                report.total.latency.line(&format!("{label} x{workers}w")),
                wl.throughput_rps(),
                report.total.batches,
                report.total.mean_occupancy()
            );
        }
    }
    println!("(matching per-allocation latencies ==> mixed precision adds no request-path overhead)");
    Ok(())
}
