//! Serving demo: multi-token decode sessions over the quantized model,
//! through the continuous-batching multi-worker router.
//!
//! Demonstrates the paper's §5.3 claim end-to-end under a DECODE load:
//! a MIXED-precision bit allocation served through the same executable
//! has the same request and inter-token latency as a uniform one at
//! equal average bits — the request path never branches on precision.
//! The worker sweep additionally shows the scaling the router buys:
//! each worker owns its own engine with device-resident weights and
//! bit grids, so adding workers multiplies decode capacity without
//! touching the request path. A final vignette walks the request
//! lifecycle explicitly: streaming a ticket token by token, then
//! cancelling a long generation mid-decode.
//!
//! Run: cargo run --release --offline --example serve_quantized
//!      [-- --requests 24 --rate 100 --workers 4 --max-new-tokens 8]

use scalebits::calib::TokenStream;
use scalebits::model::Manifest;
use scalebits::quant::{BitAlloc, BlockIndex};
use scalebits::serve::{run_workload, Finish, GenRequest, Router, ServeConfig, WorkloadSpec};
use scalebits::util::cli::Args;
use scalebits::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.usize_or("requests", 24)?;
    let rate = args.f64_or("rate", 100.0)?;
    let max_workers = args.usize_or("workers", 4)?;
    let max_new = args.usize_or("max-new-tokens", 8)?;
    // Artifact-less container (the ci.sh examples-smoke lane): with no
    // explicit --artifacts and no artifacts/ dir, synthesize the
    // deterministic model; BackendKind::Auto then resolves to the
    // interpreter (no HLO files present). An explicit path must exist.
    let artifacts = scalebits::model::synth::artifacts_or_synth(
        args.str_opt("artifacts").map(String::from),
        "example",
    )?;

    let m = Manifest::load(&artifacts)?;
    let index = BlockIndex::from_manifest(&m)?;
    let stream = TokenStream::from_manifest(&m, "eval")?;
    let seq = m.config.seq_len;

    // Allocation A: uniform 4-bit. Allocation B: mixed 2/4/8 at avg 4.
    let uniform = BitAlloc::uniform(&index, 4);
    let mut mixed = BitAlloc::uniform(&index, 4);
    let mut rng = Rng::new(9);
    for b in mixed.bits.iter_mut() {
        *b = match rng.below(10) {
            0..=3 => 2,
            4..=7 => 4,
            _ => 8,
        };
    }
    println!(
        "uniform avg bits {:.2} | mixed avg bits {:.2} (40% INT2 / 40% INT4 / 20% INT8), \
         {max_new} tokens per request",
        uniform.avg_bits(),
        mixed.avg_bits()
    );

    let sweeps: Vec<usize> = if max_workers > 1 { vec![1, max_workers] } else { vec![1] };
    for (label, alloc) in [("uniform-4bit", uniform.clone()), ("mixed-2/4/8", mixed)] {
        for &workers in &sweeps {
            let mut cfg = ServeConfig::new(artifacts.clone(), alloc.clone());
            cfg.workers = workers;
            let mut server = Router::start(cfg)?;
            let spec = WorkloadSpec::new(seq, n, rate, 7).max_new_tokens(max_new);
            let wl = run_workload(&mut server, &stream, &spec)?;
            let report = server.shutdown()?;
            println!(
                "{} | {:.1} req/s, {:.1} tok/s, decode depth {:.2}",
                report.total.inter_token.line(&format!("ITL {label} x{workers}w")),
                wl.throughput_rps(),
                wl.decode_tps(),
                report.total.mean_decode_depth()
            );
        }
    }
    println!("(matching per-allocation latencies ==> mixed precision adds no request-path overhead)");

    // -- lifecycle vignette: stream one ticket, cancel another --------
    let mut cfg = ServeConfig::new(artifacts.clone(), uniform);
    cfg.workers = 1;
    let mut server = Router::start(cfg)?;
    let mut streamed = server
        .submit_request(GenRequest::new(stream.tokens[..seq].to_vec()).max_new_tokens(4))?;
    print!("streamed tokens:");
    while let Some(ev) = streamed.recv_token()? {
        print!(" {} (+{:.0}us)", ev.token, ev.latency.as_secs_f64() * 1e6);
    }
    println!(" -> {}", streamed.outcome().expect("terminal").finish.name());

    let mut doomed = server
        .submit_request(GenRequest::new(stream.tokens[..seq].to_vec()).max_new_tokens(1_000_000))?;
    doomed.try_cancel();
    let outcome = doomed.wait()?;
    assert_eq!(outcome.finish, Finish::Cancelled);
    println!(
        "cancelled after {} token(s): finish = {}",
        outcome.tokens.len(),
        outcome.finish.name()
    );

    // -- chunked prefill: a LONG prompt trickles through the step
    // batch a few tokens per iteration, so a short request admitted
    // behind it completes first instead of stalling on the prefill --
    let mut long = server.submit_request(
        GenRequest::new(stream.tokens[..4 * seq].to_vec()).max_new_tokens(2).prefill_chunk(4),
    )?;
    let mut short =
        server.submit_request(GenRequest::new(stream.tokens[seq..2 * seq].to_vec()))?;
    let short_outcome = short.wait()?;
    let long_still_prefilling = long.poll()?.is_none();
    let long_outcome = long.wait()?;
    println!(
        "chunked prefill: short request finished ({}) while the 4x-window prompt {} \
         (long finish = {})",
        short_outcome.finish.name(),
        if long_still_prefilling { "was still prefilling" } else { "had finished" },
        long_outcome.finish.name()
    );
    server.shutdown()?;
    Ok(())
}
