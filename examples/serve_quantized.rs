//! Serving demo: batched next-token service over the quantized model.
//!
//! Demonstrates the paper's §5.3 claim end-to-end: a MIXED-precision
//! bit allocation served through the same compiled executable has the
//! same latency as a uniform one at equal average bits — the request
//! path never branches on precision.
//!
//! Run: cargo run --release --offline --example serve_quantized [-- --requests 24]

use std::time::Duration;

use scalebits::calib::TokenStream;
use scalebits::model::Manifest;
use scalebits::quant::{BitAlloc, BlockIndex};
use scalebits::serve::{run_workload, start_server};
use scalebits::util::cli::Args;
use scalebits::util::rng::Rng;
use scalebits::util::timer::Stats;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.usize_or("requests", 24)?;
    let rate = args.f64_or("rate", 100.0)?;
    let artifacts = std::path::PathBuf::from(args.str_or("artifacts", "artifacts"));

    let m = Manifest::load(&artifacts)?;
    let index = BlockIndex::from_manifest(&m)?;
    let stream = TokenStream::from_manifest(&m, "eval")?;
    let seq = m.config.seq_len;

    // Allocation A: uniform 4-bit. Allocation B: mixed 2/4/8 at avg 4.
    let uniform = BitAlloc::uniform(&index, 4);
    let mut mixed = BitAlloc::uniform(&index, 4);
    let mut rng = Rng::new(9);
    for i in 0..mixed.bits.len() {
        mixed.bits[i] = match rng.below(10) {
            0..=3 => 2,
            4..=7 => 4,
            _ => 8,
        };
        let _ = i;
    }
    println!(
        "uniform avg bits {:.2} | mixed avg bits {:.2} (40% INT2 / 40% INT4 / 20% INT8)",
        uniform.avg_bits(),
        mixed.avg_bits()
    );

    for (label, alloc) in [("uniform-4bit", uniform), ("mixed-2/4/8", mixed)] {
        let mut server = start_server(artifacts.clone(), alloc, Duration::from_millis(3))?;
        let lats = run_workload(&mut server, &stream, seq, n, rate, 7)?;
        let stats = server.shutdown()?;
        let s = Stats::from_samples_us(lats.iter().map(|x| x * 1e6).collect());
        println!(
            "{label:<14} {} | {} batches, mean occupancy {:.2}",
            s.line("latency"),
            stats.batches,
            stats.mean_occupancy()
        );
    }
    println!("(matching mean latencies ==> mixed precision adds no request-path overhead)");
    Ok(())
}
